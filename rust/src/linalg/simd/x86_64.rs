//! x86_64 AVX2+FMA kernel.
//!
//! Tile geometry is 4×12: a 4×3 grid of `__m256d` accumulators (12
//! registers) plus three B row vectors and one A broadcast exactly fills
//! the 16-register ymm file with no accumulator spills — the classic
//! f64 GEMM shape for this ISA. (A literal 4×4 grid would need 16
//! accumulator registers and spill every iteration.) Per packed `kk`:
//! three 4-wide B loads, four A broadcasts, twelve `_mm256_fmadd_pd`.
//!
//! Every operation reproduces the scalar contract bit-for-bit (see
//! [`super::scalar`]): the tile is one hardware-FMA chain per element in
//! ascending k — the same correctly-rounded operation sequence as the
//! scalar arm's `f64::mul_add` — and the sweeps are per-lane mul/add/div
//! with the scalar 4-lane reduction order for the horizontal ops.

use super::MicroKernel;
use core::arch::x86_64::*;

/// Register-tile rows of the AVX2 kernel.
pub const MR: usize = 4;
/// Register-tile columns of the AVX2 kernel (three `__m256d` per row).
pub const NR: usize = 12;

/// The AVX2+FMA dispatch arm.
pub struct Avx2;

impl super::sealed::Sealed for Avx2 {}

impl MicroKernel for Avx2 {
    const NAME: &'static str = "avx2+fma";
    const MR: usize = MR;
    const NR: usize = NR;

    fn supported() -> bool {
        std::arch::is_x86_feature_detected!("avx2") && std::arch::is_x86_feature_detected!("fma")
    }

    unsafe fn tile(pa: &[f64], pb: &[f64], kc: usize, out: &mut [f64]) {
        tile(pa, pb, kc, out)
    }

    unsafe fn dot(a: &[f64], b: &[f64]) -> f64 {
        dot(a, b)
    }

    unsafe fn weighted_sumsq(w: &[f64], v: &[f64]) -> f64 {
        weighted_sumsq(w, v)
    }

    unsafe fn axpy(y: &mut [f64], alpha: f64, x: &[f64]) {
        axpy(y, alpha, x)
    }

    unsafe fn scale(y: &mut [f64], alpha: f64) {
        scale(y, alpha)
    }

    unsafe fn div_assign(y: &mut [f64], d: f64) {
        div_assign(y, d)
    }

    unsafe fn mul_into(out: &mut [f64], a: &[f64], b: &[f64]) {
        mul_into(out, a, b)
    }

    unsafe fn square_into(out: &mut [f64], a: &[f64]) {
        square_into(out, a)
    }

    unsafe fn marginal_weights(out: &mut [f64], lam: &[f64]) {
        marginal_weights(out, lam)
    }

    unsafe fn dp_row(cur: &mut [f64], prev: &[f64], lam: f64) {
        dp_row(cur, prev, lam)
    }
}

#[target_feature(enable = "avx2")]
#[target_feature(enable = "fma")]
unsafe fn tile(pa: &[f64], pb: &[f64], kc: usize, out: &mut [f64]) {
    debug_assert!(pa.len() >= MR * kc && pb.len() >= NR * kc && out.len() >= MR * NR);
    let (pa, pb) = (pa.as_ptr(), pb.as_ptr());
    let mut acc = [[_mm256_setzero_pd(); 3]; MR];
    for kk in 0..kc {
        let b0 = _mm256_loadu_pd(pb.add(kk * NR));
        let b1 = _mm256_loadu_pd(pb.add(kk * NR + 4));
        let b2 = _mm256_loadu_pd(pb.add(kk * NR + 8));
        for (r, arow) in acc.iter_mut().enumerate() {
            let ar = _mm256_broadcast_sd(&*pa.add(kk * MR + r));
            arow[0] = _mm256_fmadd_pd(ar, b0, arow[0]);
            arow[1] = _mm256_fmadd_pd(ar, b1, arow[1]);
            arow[2] = _mm256_fmadd_pd(ar, b2, arow[2]);
        }
    }
    let op = out.as_mut_ptr();
    for (r, arow) in acc.iter().enumerate() {
        _mm256_storeu_pd(op.add(r * NR), arow[0]);
        _mm256_storeu_pd(op.add(r * NR + 4), arow[1]);
        _mm256_storeu_pd(op.add(r * NR + 8), arow[2]);
    }
}

/// Horizontal sum in the scalar contract's order: `((s0+s1)+s2)+s3`.
#[target_feature(enable = "avx2")]
unsafe fn hsum_ordered(acc: __m256d) -> f64 {
    let mut lanes = [0.0f64; 4];
    _mm256_storeu_pd(lanes.as_mut_ptr(), acc);
    ((lanes[0] + lanes[1]) + lanes[2]) + lanes[3]
}

#[target_feature(enable = "avx2")]
unsafe fn dot(a: &[f64], b: &[f64]) -> f64 {
    let n = a.len();
    let chunks = n / 4;
    let (pa, pb) = (a.as_ptr(), b.as_ptr());
    // One accumulator whose lane l is exactly the scalar arm's partial
    // sum s_l (mul then add per lane — not FMA, matching the sweep
    // contract's two-rounding semantics).
    let mut acc = _mm256_setzero_pd();
    for c in 0..chunks {
        let av = _mm256_loadu_pd(pa.add(4 * c));
        let bv = _mm256_loadu_pd(pb.add(4 * c));
        acc = _mm256_add_pd(acc, _mm256_mul_pd(av, bv));
    }
    let mut s = hsum_ordered(acc);
    for i in chunks * 4..n {
        s += *pa.add(i) * *pb.add(i);
    }
    s
}

#[target_feature(enable = "avx2")]
unsafe fn weighted_sumsq(w: &[f64], v: &[f64]) -> f64 {
    let n = w.len();
    let chunks = n / 4;
    let (pw, pv) = (w.as_ptr(), v.as_ptr());
    let mut acc = _mm256_setzero_pd();
    for c in 0..chunks {
        let wv = _mm256_loadu_pd(pw.add(4 * c));
        let vv = _mm256_loadu_pd(pv.add(4 * c));
        acc = _mm256_add_pd(acc, _mm256_mul_pd(_mm256_mul_pd(wv, vv), vv));
    }
    let mut s = hsum_ordered(acc);
    for i in chunks * 4..n {
        s += (*pw.add(i) * *pv.add(i)) * *pv.add(i);
    }
    s
}

#[target_feature(enable = "avx2")]
unsafe fn axpy(y: &mut [f64], alpha: f64, x: &[f64]) {
    let n = y.len();
    let chunks = n / 4;
    let va = _mm256_set1_pd(alpha);
    let (py, px) = (y.as_mut_ptr(), x.as_ptr());
    for c in 0..chunks {
        let yv = _mm256_loadu_pd(py.add(4 * c));
        let xv = _mm256_loadu_pd(px.add(4 * c));
        _mm256_storeu_pd(py.add(4 * c), _mm256_add_pd(yv, _mm256_mul_pd(va, xv)));
    }
    for i in chunks * 4..n {
        *py.add(i) += alpha * *px.add(i);
    }
}

#[target_feature(enable = "avx2")]
unsafe fn scale(y: &mut [f64], alpha: f64) {
    let n = y.len();
    let chunks = n / 4;
    let va = _mm256_set1_pd(alpha);
    let py = y.as_mut_ptr();
    for c in 0..chunks {
        let yv = _mm256_loadu_pd(py.add(4 * c));
        _mm256_storeu_pd(py.add(4 * c), _mm256_mul_pd(yv, va));
    }
    for i in chunks * 4..n {
        *py.add(i) *= alpha;
    }
}

#[target_feature(enable = "avx2")]
unsafe fn div_assign(y: &mut [f64], d: f64) {
    let n = y.len();
    let chunks = n / 4;
    let vd = _mm256_set1_pd(d);
    let py = y.as_mut_ptr();
    for c in 0..chunks {
        let yv = _mm256_loadu_pd(py.add(4 * c));
        _mm256_storeu_pd(py.add(4 * c), _mm256_div_pd(yv, vd));
    }
    for i in chunks * 4..n {
        *py.add(i) /= d;
    }
}

#[target_feature(enable = "avx2")]
unsafe fn mul_into(out: &mut [f64], a: &[f64], b: &[f64]) {
    let n = out.len();
    let chunks = n / 4;
    let (po, pa, pb) = (out.as_mut_ptr(), a.as_ptr(), b.as_ptr());
    for c in 0..chunks {
        let av = _mm256_loadu_pd(pa.add(4 * c));
        let bv = _mm256_loadu_pd(pb.add(4 * c));
        _mm256_storeu_pd(po.add(4 * c), _mm256_mul_pd(av, bv));
    }
    for i in chunks * 4..n {
        *po.add(i) = *pa.add(i) * *pb.add(i);
    }
}

#[target_feature(enable = "avx2")]
unsafe fn square_into(out: &mut [f64], a: &[f64]) {
    let n = out.len();
    let chunks = n / 4;
    let (po, pa) = (out.as_mut_ptr(), a.as_ptr());
    for c in 0..chunks {
        let av = _mm256_loadu_pd(pa.add(4 * c));
        _mm256_storeu_pd(po.add(4 * c), _mm256_mul_pd(av, av));
    }
    for i in chunks * 4..n {
        let v = *pa.add(i);
        *po.add(i) = v * v;
    }
}

#[target_feature(enable = "avx2")]
unsafe fn marginal_weights(out: &mut [f64], lam: &[f64]) {
    let n = out.len();
    let chunks = n / 4;
    let zero = _mm256_setzero_pd();
    let one = _mm256_set1_pd(1.0);
    let (po, pl) = (out.as_mut_ptr(), lam.as_ptr());
    for c in 0..chunks {
        let lv = _mm256_loadu_pd(pl.add(4 * c));
        // maxpd returns the second operand when either input is NaN or
        // both are ±0 — exactly the scalar `if l > 0 { l } else { 0 }`.
        let lp = _mm256_max_pd(lv, zero);
        _mm256_storeu_pd(po.add(4 * c), _mm256_div_pd(lp, _mm256_add_pd(one, lp)));
    }
    for i in chunks * 4..n {
        let l = *pl.add(i);
        let lp = if l > 0.0 { l } else { 0.0 };
        *po.add(i) = lp / (1.0 + lp);
    }
}

#[target_feature(enable = "avx2")]
unsafe fn dp_row(cur: &mut [f64], prev: &[f64], lam: f64) {
    let n = cur.len();
    if n == 0 {
        return;
    }
    let (pc, pp) = (cur.as_mut_ptr(), prev.as_ptr());
    *pc = *pp;
    let vl = _mm256_set1_pd(lam);
    let body = n - 1;
    let chunks = body / 4;
    for c in 0..chunks {
        let j = 1 + 4 * c;
        let pj = _mm256_loadu_pd(pp.add(j));
        let pjm1 = _mm256_loadu_pd(pp.add(j - 1));
        _mm256_storeu_pd(pc.add(j), _mm256_add_pd(pj, _mm256_mul_pd(vl, pjm1)));
    }
    for j in 1 + chunks * 4..n {
        *pc.add(j) = *pp.add(j) + lam * *pp.add(j - 1);
    }
}
