//! aarch64 NEON kernel.
//!
//! Tile geometry is 8×6: an 8×3 grid of `float64x2_t` accumulators (24 of
//! the 32 NEON registers), three B pair-loads and scalar A broadcasts via
//! `vfmaq_n_f64` — fused multiply-add, the same correctly-rounded
//! operation as the scalar arm's `f64::mul_add` and AVX2's
//! `_mm256_fmadd_pd`, so the three arms agree bitwise. The flat sweeps use
//! mul-then-add per lane under the scalar arm's 4-lane reduction contract
//! (lanes split across two 2-wide accumulators).

use super::MicroKernel;
use core::arch::aarch64::*;

/// Register-tile rows of the NEON kernel.
pub const MR: usize = 8;
/// Register-tile columns of the NEON kernel (three `float64x2_t` per row).
pub const NR: usize = 6;

/// The NEON dispatch arm.
pub struct Neon;

impl super::sealed::Sealed for Neon {}

impl MicroKernel for Neon {
    const NAME: &'static str = "neon";
    const MR: usize = MR;
    const NR: usize = NR;

    fn supported() -> bool {
        std::arch::is_aarch64_feature_detected!("neon")
    }

    unsafe fn tile(pa: &[f64], pb: &[f64], kc: usize, out: &mut [f64]) {
        tile(pa, pb, kc, out)
    }

    unsafe fn dot(a: &[f64], b: &[f64]) -> f64 {
        dot(a, b)
    }

    unsafe fn weighted_sumsq(w: &[f64], v: &[f64]) -> f64 {
        weighted_sumsq(w, v)
    }

    unsafe fn axpy(y: &mut [f64], alpha: f64, x: &[f64]) {
        axpy(y, alpha, x)
    }

    unsafe fn scale(y: &mut [f64], alpha: f64) {
        scale(y, alpha)
    }

    unsafe fn div_assign(y: &mut [f64], d: f64) {
        div_assign(y, d)
    }

    unsafe fn mul_into(out: &mut [f64], a: &[f64], b: &[f64]) {
        mul_into(out, a, b)
    }

    unsafe fn square_into(out: &mut [f64], a: &[f64]) {
        square_into(out, a)
    }

    unsafe fn marginal_weights(out: &mut [f64], lam: &[f64]) {
        marginal_weights(out, lam)
    }

    unsafe fn dp_row(cur: &mut [f64], prev: &[f64], lam: f64) {
        dp_row(cur, prev, lam)
    }
}

#[target_feature(enable = "neon")]
unsafe fn tile(pa: &[f64], pb: &[f64], kc: usize, out: &mut [f64]) {
    debug_assert!(pa.len() >= MR * kc && pb.len() >= NR * kc && out.len() >= MR * NR);
    let (pa, pb) = (pa.as_ptr(), pb.as_ptr());
    let mut acc = [[vdupq_n_f64(0.0); 3]; MR];
    for kk in 0..kc {
        let b0 = vld1q_f64(pb.add(kk * NR));
        let b1 = vld1q_f64(pb.add(kk * NR + 2));
        let b2 = vld1q_f64(pb.add(kk * NR + 4));
        for (r, arow) in acc.iter_mut().enumerate() {
            let ar = *pa.add(kk * MR + r);
            arow[0] = vfmaq_n_f64(arow[0], b0, ar);
            arow[1] = vfmaq_n_f64(arow[1], b1, ar);
            arow[2] = vfmaq_n_f64(arow[2], b2, ar);
        }
    }
    let op = out.as_mut_ptr();
    for (r, arow) in acc.iter().enumerate() {
        vst1q_f64(op.add(r * NR), arow[0]);
        vst1q_f64(op.add(r * NR + 2), arow[1]);
        vst1q_f64(op.add(r * NR + 4), arow[2]);
    }
}

/// Combine the two 2-lane accumulators (lanes s0,s1 and s2,s3) in the
/// scalar contract's order `((s0+s1)+s2)+s3`.
#[target_feature(enable = "neon")]
unsafe fn hsum_ordered(acc01: float64x2_t, acc23: float64x2_t) -> f64 {
    ((vgetq_lane_f64::<0>(acc01) + vgetq_lane_f64::<1>(acc01)) + vgetq_lane_f64::<0>(acc23))
        + vgetq_lane_f64::<1>(acc23)
}

#[target_feature(enable = "neon")]
unsafe fn dot(a: &[f64], b: &[f64]) -> f64 {
    let n = a.len();
    let chunks = n / 4;
    let (pa, pb) = (a.as_ptr(), b.as_ptr());
    let mut acc01 = vdupq_n_f64(0.0);
    let mut acc23 = vdupq_n_f64(0.0);
    for c in 0..chunks {
        let i = 4 * c;
        acc01 = vaddq_f64(acc01, vmulq_f64(vld1q_f64(pa.add(i)), vld1q_f64(pb.add(i))));
        acc23 =
            vaddq_f64(acc23, vmulq_f64(vld1q_f64(pa.add(i + 2)), vld1q_f64(pb.add(i + 2))));
    }
    let mut s = hsum_ordered(acc01, acc23);
    for i in chunks * 4..n {
        s += *pa.add(i) * *pb.add(i);
    }
    s
}

#[target_feature(enable = "neon")]
unsafe fn weighted_sumsq(w: &[f64], v: &[f64]) -> f64 {
    let n = w.len();
    let chunks = n / 4;
    let (pw, pv) = (w.as_ptr(), v.as_ptr());
    let mut acc01 = vdupq_n_f64(0.0);
    let mut acc23 = vdupq_n_f64(0.0);
    for c in 0..chunks {
        let i = 4 * c;
        let (w0, v0) = (vld1q_f64(pw.add(i)), vld1q_f64(pv.add(i)));
        let (w1, v1) = (vld1q_f64(pw.add(i + 2)), vld1q_f64(pv.add(i + 2)));
        acc01 = vaddq_f64(acc01, vmulq_f64(vmulq_f64(w0, v0), v0));
        acc23 = vaddq_f64(acc23, vmulq_f64(vmulq_f64(w1, v1), v1));
    }
    let mut s = hsum_ordered(acc01, acc23);
    for i in chunks * 4..n {
        s += (*pw.add(i) * *pv.add(i)) * *pv.add(i);
    }
    s
}

#[target_feature(enable = "neon")]
unsafe fn axpy(y: &mut [f64], alpha: f64, x: &[f64]) {
    let n = y.len();
    let chunks = n / 2;
    let va = vdupq_n_f64(alpha);
    let (py, px) = (y.as_mut_ptr(), x.as_ptr());
    for c in 0..chunks {
        let i = 2 * c;
        let yv = vld1q_f64(py.add(i));
        let xv = vld1q_f64(px.add(i));
        vst1q_f64(py.add(i), vaddq_f64(yv, vmulq_f64(va, xv)));
    }
    for i in chunks * 2..n {
        *py.add(i) += alpha * *px.add(i);
    }
}

#[target_feature(enable = "neon")]
unsafe fn scale(y: &mut [f64], alpha: f64) {
    let n = y.len();
    let chunks = n / 2;
    let va = vdupq_n_f64(alpha);
    let py = y.as_mut_ptr();
    for c in 0..chunks {
        let i = 2 * c;
        vst1q_f64(py.add(i), vmulq_f64(vld1q_f64(py.add(i)), va));
    }
    for i in chunks * 2..n {
        *py.add(i) *= alpha;
    }
}

#[target_feature(enable = "neon")]
unsafe fn div_assign(y: &mut [f64], d: f64) {
    let n = y.len();
    let chunks = n / 2;
    let vd = vdupq_n_f64(d);
    let py = y.as_mut_ptr();
    for c in 0..chunks {
        let i = 2 * c;
        vst1q_f64(py.add(i), vdivq_f64(vld1q_f64(py.add(i)), vd));
    }
    for i in chunks * 2..n {
        *py.add(i) /= d;
    }
}

#[target_feature(enable = "neon")]
unsafe fn mul_into(out: &mut [f64], a: &[f64], b: &[f64]) {
    let n = out.len();
    let chunks = n / 2;
    let (po, pa, pb) = (out.as_mut_ptr(), a.as_ptr(), b.as_ptr());
    for c in 0..chunks {
        let i = 2 * c;
        vst1q_f64(po.add(i), vmulq_f64(vld1q_f64(pa.add(i)), vld1q_f64(pb.add(i))));
    }
    for i in chunks * 2..n {
        *po.add(i) = *pa.add(i) * *pb.add(i);
    }
}

#[target_feature(enable = "neon")]
unsafe fn square_into(out: &mut [f64], a: &[f64]) {
    let n = out.len();
    let chunks = n / 2;
    let (po, pa) = (out.as_mut_ptr(), a.as_ptr());
    for c in 0..chunks {
        let i = 2 * c;
        let av = vld1q_f64(pa.add(i));
        vst1q_f64(po.add(i), vmulq_f64(av, av));
    }
    for i in chunks * 2..n {
        let v = *pa.add(i);
        *po.add(i) = v * v;
    }
}

#[target_feature(enable = "neon")]
unsafe fn marginal_weights(out: &mut [f64], lam: &[f64]) {
    let n = out.len();
    let chunks = n / 2;
    let zero = vdupq_n_f64(0.0);
    let one = vdupq_n_f64(1.0);
    let (po, pl) = (out.as_mut_ptr(), lam.as_ptr());
    for c in 0..chunks {
        let i = 2 * c;
        // FMAXNM: a NaN operand yields the numeric operand (here 0) and
        // max(−0, +0) = +0 — exactly the scalar `if l > 0 { l } else { 0 }`.
        let lp = vmaxnmq_f64(vld1q_f64(pl.add(i)), zero);
        vst1q_f64(po.add(i), vdivq_f64(lp, vaddq_f64(one, lp)));
    }
    for i in chunks * 2..n {
        let l = *pl.add(i);
        let lp = if l > 0.0 { l } else { 0.0 };
        *po.add(i) = lp / (1.0 + lp);
    }
}

#[target_feature(enable = "neon")]
unsafe fn dp_row(cur: &mut [f64], prev: &[f64], lam: f64) {
    let n = cur.len();
    if n == 0 {
        return;
    }
    let (pc, pp) = (cur.as_mut_ptr(), prev.as_ptr());
    *pc = *pp;
    let vl = vdupq_n_f64(lam);
    let body = n - 1;
    let chunks = body / 2;
    for c in 0..chunks {
        let j = 1 + 2 * c;
        let pj = vld1q_f64(pp.add(j));
        let pjm1 = vld1q_f64(pp.add(j - 1));
        vst1q_f64(pc.add(j), vaddq_f64(pj, vmulq_f64(vl, pjm1)));
    }
    for j in 1 + chunks * 2..n {
        *pc.add(j) = *pp.add(j) + lam * *pp.add(j - 1);
    }
}
