//! Portable scalar kernel — the reference implementation and oracle.
//!
//! Every operation here *defines* the bit-exact semantics that the
//! vectorized kernels ([`super::x86_64`], [`super::aarch64`]) must
//! reproduce. Two different contracts are in play:
//!
//! - **GEMM micro-kernel** ([`tile`]): each output element is one fused
//!   multiply-add chain over the packed depth (`f64::mul_add`, which is the
//!   IEEE-754 correctly-rounded `fma`). AVX2's `_mm256_fmadd_pd` and NEON's
//!   `vfmaq_n_f64` perform the same single-rounding operation per lane, so
//!   all three kernels agree bitwise. On hardware without FMA the libm
//!   `fma` fallback is slow — acceptable, because that is exactly the
//!   hardware where this scalar kernel is the *only* arm, and the forced-
//!   scalar CI arm only runs small tier-1 shapes.
//! - **Flat sweeps** (dot/axpy/scale/…): plain mul-then-add per element
//!   (two roundings), matching what these helpers have always computed.
//!   The vector arms use mul+add per lane — identical rounding — so the
//!   sweeps also agree bitwise across arms.
//!
//! [`dot`] additionally fixes a *reduction order*: four partial sums over
//! index classes `i mod 4`, combined as `((s0+s1)+s2)+s3`, then a scalar
//! tail. The AVX2 arm maps the four classes onto the four lanes of one
//! accumulator and NEON onto two 2-lane accumulators, so the order — and
//! therefore the bits — never change with the dispatch arm.

use super::MicroKernel;

/// The portable fallback kernel (also the conformance oracle).
pub struct Scalar;

impl super::sealed::Sealed for Scalar {}

/// Register-tile rows of the scalar micro-kernel.
pub const MR: usize = 8;
/// Register-tile columns of the scalar micro-kernel.
pub const NR: usize = 4;

impl MicroKernel for Scalar {
    const NAME: &'static str = "scalar";
    const MR: usize = MR;
    const NR: usize = NR;

    fn supported() -> bool {
        true
    }

    unsafe fn tile(pa: &[f64], pb: &[f64], kc: usize, out: &mut [f64]) {
        tile(pa, pb, kc, out)
    }
}

/// 8×4 register tile over packed panels: `out[r·NR + c] = Σ_kk fma(a, b)`.
/// One `mul_add` chain per output element, `kk` ascending — the reduction
/// order every vector kernel reproduces lane-for-lane.
pub(super) fn tile(pa: &[f64], pb: &[f64], kc: usize, out: &mut [f64]) {
    debug_assert!(pa.len() >= MR * kc && pb.len() >= NR * kc && out.len() >= MR * NR);
    let mut acc = [[0.0f64; NR]; MR];
    for kk in 0..kc {
        let a = &pa[kk * MR..kk * MR + MR];
        let b = &pb[kk * NR..kk * NR + NR];
        for r in 0..MR {
            let ar = a[r];
            for c in 0..NR {
                acc[r][c] = ar.mul_add(b[c], acc[r][c]);
            }
        }
    }
    for (r, arow) in acc.iter().enumerate() {
        out[r * NR..r * NR + NR].copy_from_slice(arow);
    }
}

/// Dot product: four partial sums over `i mod 4`, combined
/// `((s0+s1)+s2)+s3`, scalar tail. This *is* the cross-arch contract.
pub(super) fn dot(a: &[f64], b: &[f64]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    let chunks = a.len() / 4;
    let (mut s0, mut s1, mut s2, mut s3) = (0.0f64, 0.0f64, 0.0f64, 0.0f64);
    for c in 0..chunks {
        let i = c * 4;
        s0 += a[i] * b[i];
        s1 += a[i + 1] * b[i + 1];
        s2 += a[i + 2] * b[i + 2];
        s3 += a[i + 3] * b[i + 3];
    }
    let mut s = s0 + s1 + s2 + s3;
    for i in chunks * 4..a.len() {
        s += a[i] * b[i];
    }
    s
}

/// Weighted sum of squares `Σ_i (w[i]·v[i])·v[i]` under the same 4-lane
/// reduction contract as [`dot`] — the dense marginal-diagonal sweep.
pub(super) fn weighted_sumsq(w: &[f64], v: &[f64]) -> f64 {
    debug_assert_eq!(w.len(), v.len());
    let chunks = w.len() / 4;
    let (mut s0, mut s1, mut s2, mut s3) = (0.0f64, 0.0f64, 0.0f64, 0.0f64);
    for c in 0..chunks {
        let i = c * 4;
        s0 += (w[i] * v[i]) * v[i];
        s1 += (w[i + 1] * v[i + 1]) * v[i + 1];
        s2 += (w[i + 2] * v[i + 2]) * v[i + 2];
        s3 += (w[i + 3] * v[i + 3]) * v[i + 3];
    }
    let mut s = s0 + s1 + s2 + s3;
    for i in chunks * 4..w.len() {
        s += (w[i] * v[i]) * v[i];
    }
    s
}

/// `y += alpha·x`, element-wise mul-then-add.
pub(super) fn axpy(y: &mut [f64], alpha: f64, x: &[f64]) {
    debug_assert_eq!(y.len(), x.len());
    for (yi, xi) in y.iter_mut().zip(x) {
        *yi += alpha * xi;
    }
}

/// `y *= alpha`.
pub(super) fn scale(y: &mut [f64], alpha: f64) {
    for v in y.iter_mut() {
        *v *= alpha;
    }
}

/// `y /= d` (true division per element — never a reciprocal multiply,
/// so the bits match the pre-dispatch substitution sweeps).
pub(super) fn div_assign(y: &mut [f64], d: f64) {
    for v in y.iter_mut() {
        *v /= d;
    }
}

/// `out[i] = a[i]·b[i]`.
pub(super) fn mul_into(out: &mut [f64], a: &[f64], b: &[f64]) {
    debug_assert!(out.len() == a.len() && out.len() == b.len());
    for (o, (av, bv)) in out.iter_mut().zip(a.iter().zip(b)) {
        *o = av * bv;
    }
}

/// `out[i] = a[i]²` — the squared-eigenvector GEMM feed.
pub(super) fn square_into(out: &mut [f64], a: &[f64]) {
    debug_assert_eq!(out.len(), a.len());
    for (o, av) in out.iter_mut().zip(a) {
        *o = av * av;
    }
}

/// `out[i] = λ⁺/(1+λ⁺)` with `λ⁺ = max(λ, 0)` — the marginal-diagonal
/// weight grid. The clamp is written as a compare-select so the vector
/// `max` instructions (which return the non-NaN/second operand) match it
/// bit-for-bit on every input the spectrum can produce.
pub(super) fn marginal_weights(out: &mut [f64], lam: &[f64]) {
    debug_assert_eq!(out.len(), lam.len());
    for (o, &l) in out.iter_mut().zip(lam) {
        let lp = if l > 0.0 { l } else { 0.0 };
        *o = lp / (1.0 + lp);
    }
}

/// One elementary-symmetric-polynomial DP row:
/// `cur[0] = prev[0]`, `cur[j] = prev[j] + λ·prev[j−1]` for `j ≥ 1`.
pub(super) fn dp_row(cur: &mut [f64], prev: &[f64], lam: f64) {
    debug_assert_eq!(cur.len(), prev.len());
    if cur.is_empty() {
        return;
    }
    cur[0] = prev[0];
    for (c, (p, pm1)) in cur[1..].iter_mut().zip(prev[1..].iter().zip(prev)) {
        *c = p + lam * pm1;
    }
}
