//! SIMD micro-kernels with runtime dispatch — the vector seam under the
//! linalg substrate.
//!
//! A sealed [`MicroKernel`] trait describes one architecture's f64
//! register tile (`MR×NR` over packed A/B panels) plus the flat sweeps the
//! substrate leans on (dot/axpy/scale, the `λ/(1+λ)` marginal grid, the
//! elementary-polynomial DP row). Three implementations exist:
//!
//! | kernel | tile | where |
//! |---|---|---|
//! | [`scalar::Scalar`] | 8×4, `f64::mul_add` | portable fallback & oracle |
//! | `x86_64::Avx2` | 4×12, `_mm256_fmadd_pd` | x86_64 with AVX2+FMA |
//! | `aarch64::Neon` | 8×6, `vfmaq_n_f64` | aarch64 (NEON is baseline) |
//!
//! **Dispatch order** (resolved once, cached in a `OnceLock`):
//!
//! 1. `KRONDPP_FORCE_SCALAR` set to anything but `0`/empty → scalar;
//! 2. x86_64 with `is_x86_feature_detected!("avx2")` *and* `("fma")` → AVX2;
//! 3. aarch64 (NEON is part of the baseline ISA) → NEON;
//! 4. otherwise → scalar.
//!
//! The selected [`Kernels`] table is a plain struct of function pointers —
//! no boxed trait objects, nothing allocated after the first lookup — so
//! hot paths resolve it once ([`active`]) and call through it. Every arm
//! computes **bitwise-identical** results: the micro-kernel is specified
//! as one correctly-rounded FMA chain per element in fixed k-order (scalar
//! uses `f64::mul_add`, the vector arms hardware FMA), and the sweeps as
//! per-element mul/add/div with a fixed 4-lane reduction order for the
//! horizontal ops. `tests/linalg_oracle.rs` enforces this against
//! [`scalar`] as the oracle, which is also why thread-count invariance is
//! untouched: worker partitioning never changes any element's chain, and
//! neither does the dispatch arm.

pub mod scalar;

#[cfg(target_arch = "aarch64")]
pub mod aarch64;
#[cfg(target_arch = "x86_64")]
pub mod x86_64;

mod sealed {
    pub trait Sealed {}
}

/// Upper bound on `MR` over all kernels (pack-buffer sizing).
pub const MAX_MR: usize = 16;
/// Upper bound on `NR` over all kernels (pack-buffer sizing).
pub const MAX_NR: usize = 16;
/// Upper bound on `MR·NR` — the stack tile the packed GEMM hands to
/// [`Kernels::tile`].
pub const MAX_TILE: usize = MAX_MR * MAX_NR;

/// One architecture's register-tile micro-kernel plus the vectorized flat
/// sweeps. Sealed: the three implementations in this module are the only
/// arms the conformance suite certifies, and external kernels could not
/// uphold the cross-arm bitwise contract documented at module level.
///
/// Default methods are the scalar reference sweeps, so an arch kernel
/// overrides exactly the ops it accelerates and inherits oracle semantics
/// for the rest.
pub trait MicroKernel: sealed::Sealed {
    /// Human-readable arm name (surfaced in benches and reports).
    const NAME: &'static str;
    /// Register-tile rows (micro-panel height of packed A).
    const MR: usize;
    /// Register-tile columns (micro-panel width of packed B).
    const NR: usize;

    /// Can this kernel run on the current CPU? Checked once at dispatch.
    fn supported() -> bool;

    /// `out[r·NR + c] = Σ_kk fma(pa[kk·MR + r], pb[kk·NR + c])` — the full
    /// `MR×NR` tile over one packed A/B micro-panel pair.
    ///
    /// # Safety
    /// Callable only when [`MicroKernel::supported`] returned `true` on
    /// this CPU; `pa.len() ≥ MR·kc`, `pb.len() ≥ NR·kc`,
    /// `out.len() ≥ MR·NR`.
    unsafe fn tile(pa: &[f64], pb: &[f64], kc: usize, out: &mut [f64]);

    /// Dot product under the 4-lane reduction contract.
    ///
    /// # Safety
    /// Callable only when [`MicroKernel::supported`] returned `true`;
    /// `a.len() == b.len()`.
    unsafe fn dot(a: &[f64], b: &[f64]) -> f64 {
        scalar::dot(a, b)
    }

    /// `Σ (w·v)·v` under the 4-lane reduction contract.
    ///
    /// # Safety
    /// As [`MicroKernel::dot`]; `w.len() == v.len()`.
    unsafe fn weighted_sumsq(w: &[f64], v: &[f64]) -> f64 {
        scalar::weighted_sumsq(w, v)
    }

    /// `y += alpha·x`.
    ///
    /// # Safety
    /// As [`MicroKernel::dot`]; `y.len() == x.len()`.
    unsafe fn axpy(y: &mut [f64], alpha: f64, x: &[f64]) {
        scalar::axpy(y, alpha, x)
    }

    /// `y *= alpha`.
    ///
    /// # Safety
    /// As [`MicroKernel::dot`].
    unsafe fn scale(y: &mut [f64], alpha: f64) {
        scalar::scale(y, alpha)
    }

    /// `y /= d` (true division).
    ///
    /// # Safety
    /// As [`MicroKernel::dot`].
    unsafe fn div_assign(y: &mut [f64], d: f64) {
        scalar::div_assign(y, d)
    }

    /// `out = a∘b`.
    ///
    /// # Safety
    /// As [`MicroKernel::dot`]; all three lengths equal.
    unsafe fn mul_into(out: &mut [f64], a: &[f64], b: &[f64]) {
        scalar::mul_into(out, a, b)
    }

    /// `out = a∘a`.
    ///
    /// # Safety
    /// As [`MicroKernel::dot`]; `out.len() == a.len()`.
    unsafe fn square_into(out: &mut [f64], a: &[f64]) {
        scalar::square_into(out, a)
    }

    /// `out[i] = λ⁺/(1+λ⁺)` with `λ⁺ = max(λ, 0)`.
    ///
    /// # Safety
    /// As [`MicroKernel::dot`]; `out.len() == lam.len()`.
    unsafe fn marginal_weights(out: &mut [f64], lam: &[f64]) {
        scalar::marginal_weights(out, lam)
    }

    /// One elementary-polynomial DP row:
    /// `cur[j] = prev[j] + λ·prev[j−1]`.
    ///
    /// # Safety
    /// As [`MicroKernel::dot`]; `cur.len() == prev.len()`.
    unsafe fn dp_row(cur: &mut [f64], prev: &[f64], lam: f64) {
        scalar::dp_row(cur, prev, lam)
    }
}

/// The resolved dispatch table: one arm's function pointers plus its tile
/// geometry. Only constructed for kernels whose
/// [`supported`](MicroKernel::supported) check passed (or the always-safe
/// scalar arm), which is what makes the safe wrapper methods sound.
pub struct Kernels {
    name: &'static str,
    mr: usize,
    nr: usize,
    tile: unsafe fn(&[f64], &[f64], usize, &mut [f64]),
    dot: unsafe fn(&[f64], &[f64]) -> f64,
    weighted_sumsq: unsafe fn(&[f64], &[f64]) -> f64,
    axpy: unsafe fn(&mut [f64], f64, &[f64]),
    scale: unsafe fn(&mut [f64], f64),
    div_assign: unsafe fn(&mut [f64], f64),
    mul_into: unsafe fn(&mut [f64], &[f64], &[f64]),
    square_into: unsafe fn(&mut [f64], &[f64]),
    marginal_weights: unsafe fn(&mut [f64], &[f64]),
    dp_row: unsafe fn(&mut [f64], &[f64], f64),
}

impl Kernels {
    fn of<K: MicroKernel>() -> Self {
        debug_assert!(K::MR <= MAX_MR && K::NR <= MAX_NR);
        Kernels {
            name: K::NAME,
            mr: K::MR,
            nr: K::NR,
            tile: K::tile,
            dot: K::dot,
            weighted_sumsq: K::weighted_sumsq,
            axpy: K::axpy,
            scale: K::scale,
            div_assign: K::div_assign,
            mul_into: K::mul_into,
            square_into: K::square_into,
            marginal_weights: K::marginal_weights,
            dp_row: K::dp_row,
        }
    }

    /// Arm name (`"scalar"`, `"avx2+fma"`, `"neon"`).
    pub fn name(&self) -> &'static str {
        self.name
    }

    /// Register-tile rows of this arm.
    pub fn mr(&self) -> usize {
        self.mr
    }

    /// Register-tile columns of this arm.
    pub fn nr(&self) -> usize {
        self.nr
    }

    /// Micro-kernel tile over one packed panel pair (see
    /// [`MicroKernel::tile`]). Crate-internal: only the packed GEMM feeds
    /// correctly laid-out panels.
    pub(crate) fn tile_into(&self, pa: &[f64], pb: &[f64], kc: usize, out: &mut [f64]) {
        debug_assert!(pa.len() >= self.mr * kc && pb.len() >= self.nr * kc);
        debug_assert!(out.len() >= self.mr * self.nr);
        unsafe { (self.tile)(pa, pb, kc, out) }
    }

    /// Dot product of two equal-length slices.
    pub fn dot(&self, a: &[f64], b: &[f64]) -> f64 {
        assert_eq!(a.len(), b.len(), "simd dot: length mismatch");
        unsafe { (self.dot)(a, b) }
    }

    /// `Σ (w[i]·v[i])·v[i]` over two equal-length slices.
    pub fn weighted_sumsq(&self, w: &[f64], v: &[f64]) -> f64 {
        assert_eq!(w.len(), v.len(), "simd weighted_sumsq: length mismatch");
        unsafe { (self.weighted_sumsq)(w, v) }
    }

    /// `y += alpha·x`.
    pub fn axpy(&self, y: &mut [f64], alpha: f64, x: &[f64]) {
        assert_eq!(y.len(), x.len(), "simd axpy: length mismatch");
        unsafe { (self.axpy)(y, alpha, x) }
    }

    /// `y *= alpha`.
    pub fn scale(&self, y: &mut [f64], alpha: f64) {
        unsafe { (self.scale)(y, alpha) }
    }

    /// `y /= d` (true division per element).
    pub fn div_assign(&self, y: &mut [f64], d: f64) {
        unsafe { (self.div_assign)(y, d) }
    }

    /// `out = a∘b`.
    pub fn mul_into(&self, out: &mut [f64], a: &[f64], b: &[f64]) {
        assert!(
            out.len() == a.len() && out.len() == b.len(),
            "simd mul_into: length mismatch"
        );
        unsafe { (self.mul_into)(out, a, b) }
    }

    /// `out = a∘a`.
    pub fn square_into(&self, out: &mut [f64], a: &[f64]) {
        assert_eq!(out.len(), a.len(), "simd square_into: length mismatch");
        unsafe { (self.square_into)(out, a) }
    }

    /// `out[i] = λ⁺/(1+λ⁺)`.
    pub fn marginal_weights(&self, out: &mut [f64], lam: &[f64]) {
        assert_eq!(out.len(), lam.len(), "simd marginal_weights: length mismatch");
        unsafe { (self.marginal_weights)(out, lam) }
    }

    /// One DP row `cur[j] = prev[j] + λ·prev[j−1]` (`cur[0] = prev[0]`).
    pub fn dp_row(&self, cur: &mut [f64], prev: &[f64], lam: f64) {
        assert_eq!(cur.len(), prev.len(), "simd dp_row: length mismatch");
        unsafe { (self.dp_row)(cur, prev, lam) }
    }
}

/// Was `KRONDPP_FORCE_SCALAR` set to a truthy value (anything but empty
/// or `0`)? Read once per process.
fn force_scalar() -> bool {
    match std::env::var("KRONDPP_FORCE_SCALAR") {
        Ok(v) => !v.is_empty() && v != "0",
        Err(_) => false,
    }
}

fn select() -> Kernels {
    if force_scalar() {
        return Kernels::of::<scalar::Scalar>();
    }
    #[cfg(target_arch = "x86_64")]
    {
        if x86_64::Avx2::supported() {
            return Kernels::of::<x86_64::Avx2>();
        }
    }
    #[cfg(target_arch = "aarch64")]
    {
        if aarch64::Neon::supported() {
            return Kernels::of::<aarch64::Neon>();
        }
    }
    Kernels::of::<scalar::Scalar>()
}

/// The process-wide dispatch table: feature detection runs once, the
/// result is cached, and every later call is one atomic load. Honors
/// `KRONDPP_FORCE_SCALAR` (read at first use).
pub fn active() -> &'static Kernels {
    static ACTIVE: std::sync::OnceLock<Kernels> = std::sync::OnceLock::new();
    ACTIVE.get_or_init(select)
}

/// The scalar oracle arm, always available regardless of what [`active`]
/// resolved to — the A/B seam the conformance tests and benches compare
/// against in-process.
pub fn forced_scalar() -> &'static Kernels {
    static SCALAR: std::sync::OnceLock<Kernels> = std::sync::OnceLock::new();
    SCALAR.get_or_init(Kernels::of::<scalar::Scalar>)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scalar_arm_geometry_and_name() {
        let k = forced_scalar();
        assert_eq!(k.name(), "scalar");
        assert_eq!((k.mr(), k.nr()), (8, 4));
    }

    #[test]
    fn active_arm_is_cached_and_in_bounds() {
        let k = active();
        assert!(std::ptr::eq(k, active()), "dispatch must be cached");
        assert!(k.mr() <= MAX_MR && k.nr() <= MAX_NR);
        assert!(k.mr() * k.nr() <= MAX_TILE);
    }

    #[test]
    fn dp_row_matches_shifted_recurrence() {
        let prev = [1.0, 2.5, 0.0, -3.0, 4.0];
        let mut cur = [0.0; 5];
        forced_scalar().dp_row(&mut cur, &prev, 0.7);
        assert_eq!(cur[0], prev[0]);
        for j in 1..5 {
            assert_eq!(cur[j], prev[j] + 0.7 * prev[j - 1]);
        }
    }

    #[test]
    fn sweeps_basic_semantics() {
        let k = forced_scalar();
        let a = [1.0, 2.0, 3.0, 4.0, 5.0];
        let b = [2.0, 2.0, 2.0, 2.0, 2.0];
        assert_eq!(k.dot(&a, &b), 30.0);
        let mut y = a;
        k.axpy(&mut y, 2.0, &b);
        assert_eq!(y, [5.0, 6.0, 7.0, 8.0, 9.0]);
        k.scale(&mut y, 2.0);
        assert_eq!(y[0], 10.0);
        k.div_assign(&mut y, 2.0);
        assert_eq!(y[0], 5.0);
        let mut o = [0.0; 5];
        k.mul_into(&mut o, &a, &b);
        assert_eq!(o, [2.0, 4.0, 6.0, 8.0, 10.0]);
        k.square_into(&mut o, &a);
        assert_eq!(o, [1.0, 4.0, 9.0, 16.0, 25.0]);
        let lam = [3.0, 0.0, -1.0, 1.0, 0.5];
        k.marginal_weights(&mut o, &lam);
        assert_eq!(o, [0.75, 0.0, 0.0, 0.5, 0.5 / 1.5]);
        assert_eq!(k.weighted_sumsq(&lam, &a), 3.0 - 9.0 + 16.0 + 12.5);
    }
}
