//! Dense and structured linear algebra substrate.
//!
//! Implemented from scratch (no BLAS/LAPACK available in this environment):
//! see DESIGN.md §1 for the substrate inventory. The modules mirror the
//! mathematical toolkit of the paper:
//!
//! - [`matrix`]: dense row-major `f64` container.
//! - [`matmul`]: blocked + multithreaded GEMM, Gram kernels.
//! - [`cholesky`]: PD factorization → `log det(L_Y)`, solves, inverses.
//! - [`lu`]: pivoted LU for general solves / signed determinants.
//! - [`eigen`]: symmetric eigensolver (tred2/tql2) for sampling & App. B.
//! - [`qr`]: Householder QR + the sampler's orthogonal-complement step.
//! - [`kron`]: Kronecker products, partial traces (Def. 2.3), the scaled
//!   partial-trace contractions of Prop. 3.1 / App. B.
//! - [`nkp`]: nearest Kronecker product (Van Loan–Pitsianis) for
//!   Joint-Picard (§3.2) and initializers.
//! - [`sparse`]: CSR Θ for the §3.3 memory–time trade-off and stochastic
//!   updates.

pub mod cholesky;
pub mod eigen;
pub mod kron;
pub mod lu;
pub mod matmul;
pub mod matrix;
pub mod nkp;
pub mod qr;
pub mod sparse;

pub use cholesky::Cholesky;
pub use eigen::SymEigen;
pub use lu::Lu;
pub use matrix::Matrix;
pub use qr::Qr;
pub use sparse::{SparseBuilder, SparseMatrix};
