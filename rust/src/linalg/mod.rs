//! Dense and structured linear algebra substrate.
//!
//! Implemented from scratch (no BLAS/LAPACK available in this environment):
//! see DESIGN.md §1 for the substrate inventory. The modules mirror the
//! mathematical toolkit of the paper:
//!
//! - [`matrix`]: dense row-major `f64` container.
//! - [`view`]: borrowed stride-aware views ([`MatRef`]/[`MatMut`]) — free
//!   sub-blocks and transposes, the zero-copy spine of every kernel.
//! - [`matmul`]: packed register-tiled GEMM (per-arch SIMD micro-kernel,
//!   kernel-width-aware pack buffers, row-panel parallelism) expressed
//!   once over views.
//! - [`simd`]: the runtime-dispatched micro-kernels under it — scalar /
//!   AVX2+FMA / NEON register tiles plus the vectorized flat sweeps
//!   (dot/axpy/scale, marginal-weight grids, DP rows), all bitwise
//!   equivalent across arms.
//! - [`cholesky`]: PD factorization → `log det(L_Y)`, solves, inverses.
//! - [`lu`]: pivoted LU for general solves / signed determinants.
//! - [`eigen`]: two-stage symmetric eigensolver — blocked Householder
//!   tridiagonalization (GEMM trailing updates) + tql2 with parallel
//!   back-transformation — for sampling & App. B.
//! - [`eigen_update`]: incremental eigendecomposition refresh under
//!   rank-r perturbations (deflation + secular-equation solves + one GEMM
//!   per rank) — the spectral engine of delta publishing, with tracked
//!   drift and exact-refactorization fallback.
//! - [`qr`]: Householder QR + the sampler's orthogonal-complement step.
//! - [`trisolve`]: row-oriented triangular solves with matrix RHS, shared
//!   by the three factorizations above.
//! - [`kron`]: Kronecker products, partial traces (Def. 2.3), the scaled
//!   partial-trace contractions of Prop. 3.1 / App. B.
//! - [`nkp`]: nearest Kronecker product (Van Loan–Pitsianis) for
//!   Joint-Picard (§3.2) and initializers.
//! - [`sparse`]: CSR Θ for the §3.3 memory–time trade-off and stochastic
//!   updates.

pub mod cholesky;
pub mod eigen;
pub mod eigen_update;
pub mod kron;
pub mod lu;
pub mod matmul;
pub mod matrix;
pub mod nkp;
pub mod qr;
pub mod simd;
pub mod sparse;
pub mod trisolve;
pub mod view;

pub use cholesky::Cholesky;
pub use eigen::SymEigen;
pub use lu::Lu;
pub use matrix::Matrix;
pub use qr::Qr;
pub use sparse::{SparseBuilder, SparseMatrix};
pub use view::{MatMut, MatRef};
