//! Sparse matrices for the memory–time trade-off of §3.3.
//!
//! The batch gradient term `Θ = (1/n) Σ_i U_i L_{Y_i}⁻¹ U_iᵀ` is an N×N
//! matrix whose support is `∪_i Y_i × Y_i`. When the training set is
//! partitioned by subset clustering (Eq. 9) each part's `Θ_k` touches at
//! most `z²` entries, so a COO/CSR representation brings the storage to
//! `O(mz² + N)`. The contractions that KRK-Picard needs (`A₁[k,l] =
//! Tr(Θ_(kl)L₂)` and `A₂ = Σ_{ij} L1_{ij}Θ_(ij)`) are implemented directly
//! on the sparse format, costing `O(nnz·1)` per output contribution.

use super::matrix::Matrix;
use crate::error::{Error, Result};
use std::collections::HashMap;

/// Coordinate-format sparse accumulator (duplicate-merging on build).
#[derive(Clone, Default)]
pub struct SparseBuilder {
    n: usize,
    entries: HashMap<(u32, u32), f64>,
}

impl SparseBuilder {
    /// New builder for an `n×n` matrix.
    pub fn new(n: usize) -> Self {
        SparseBuilder { n, entries: HashMap::new() }
    }

    /// Accumulate `v` at `(i, j)`.
    #[inline]
    pub fn add(&mut self, i: usize, j: usize, v: f64) {
        debug_assert!(i < self.n && j < self.n);
        *self.entries.entry((i as u32, j as u32)).or_insert(0.0) += v;
    }

    /// Scatter a dense `k×k` block onto rows/cols `idx` (the
    /// `U_i B U_iᵀ` pattern with `B = L_{Y_i}⁻¹`), scaled by `w`.
    pub fn scatter_block(&mut self, idx: &[usize], block: &Matrix, w: f64) -> Result<()> {
        let k = idx.len();
        if block.shape() != (k, k) {
            return Err(Error::Shape("scatter_block: block/index size mismatch".into()));
        }
        for (a, &i) in idx.iter().enumerate() {
            let row = block.row(a);
            for (b, &j) in idx.iter().enumerate() {
                self.add(i, j, w * row[b]);
            }
        }
        Ok(())
    }

    /// Number of stored entries so far.
    pub fn nnz(&self) -> usize {
        self.entries.len()
    }

    /// Finalize into CSR.
    pub fn build(self) -> SparseMatrix {
        let n = self.n;
        let mut triplets: Vec<(u32, u32, f64)> =
            self.entries.into_iter().map(|((i, j), v)| (i, j, v)).collect();
        triplets.sort_unstable_by_key(|&(i, j, _)| (i, j));
        let nnz = triplets.len();
        let mut row_ptr = vec![0usize; n + 1];
        let mut col_idx = Vec::with_capacity(nnz);
        let mut values = Vec::with_capacity(nnz);
        for (i, j, v) in triplets {
            row_ptr[i as usize + 1] += 1;
            col_idx.push(j);
            values.push(v);
        }
        for i in 0..n {
            row_ptr[i + 1] += row_ptr[i];
        }
        SparseMatrix { n, row_ptr, col_idx, values }
    }
}

/// CSR sparse square matrix.
#[derive(Clone)]
pub struct SparseMatrix {
    n: usize,
    row_ptr: Vec<usize>,
    col_idx: Vec<u32>,
    values: Vec<f64>,
}

impl SparseMatrix {
    /// Matrix dimension.
    pub fn n(&self) -> usize {
        self.n
    }

    /// Number of stored non-zeros.
    pub fn nnz(&self) -> usize {
        self.values.len()
    }

    /// Iterate `(row, col, value)` triplets.
    pub fn iter(&self) -> impl Iterator<Item = (usize, usize, f64)> + '_ {
        (0..self.n).flat_map(move |i| {
            (self.row_ptr[i]..self.row_ptr[i + 1])
                .map(move |k| (i, self.col_idx[k] as usize, self.values[k]))
        })
    }

    /// Densify (tests / small sizes only).
    pub fn to_dense(&self) -> Matrix {
        let mut m = Matrix::zeros(self.n, self.n);
        for (i, j, v) in self.iter() {
            m.set(i, j, m.get(i, j) + v);
        }
        m
    }

    /// `y = S·x`.
    pub fn matvec(&self, x: &[f64]) -> Result<Vec<f64>> {
        if x.len() != self.n {
            return Err(Error::Shape("sparse matvec: length mismatch".into()));
        }
        let mut y = vec![0.0; self.n];
        for i in 0..self.n {
            let mut acc = 0.0;
            for k in self.row_ptr[i]..self.row_ptr[i + 1] {
                acc += self.values[k] * x[self.col_idx[k] as usize];
            }
            y[i] = acc;
        }
        Ok(y)
    }

    /// Scale all values in place.
    pub fn scale_mut(&mut self, s: f64) {
        for v in &mut self.values {
            *v *= s;
        }
    }

    /// Block-trace contraction against a dense `n2×n2` matrix:
    /// `A[k,l] = Tr(S_(kl) · B) = Σ S_(kl)[p,q]·B[q,p]` — `O(nnz)`.
    /// This is the sparse-Θ form of the `A₁` matrix (App. B.1); with Θ
    /// holding `κ²` non-zeros it realizes the `O(N₁²κ²)`→`O(κ²)` term of
    /// Thm. 3.3's stochastic complexity.
    pub fn block_trace(&self, b: &Matrix, n1: usize, n2: usize) -> Result<Matrix> {
        let mut a = Matrix::zeros(0, 0);
        self.block_trace_into(b, n1, n2, &mut a)?;
        Ok(a)
    }

    /// [`SparseMatrix::block_trace`] into a caller-held output
    /// (allocation-free once `out` has capacity — the stochastic learner's
    /// per-step path).
    pub fn block_trace_into(
        &self,
        b: &Matrix,
        n1: usize,
        n2: usize,
        out: &mut Matrix,
    ) -> Result<()> {
        self.check_kron(b, n1, n2, b.rows() == n2)?;
        out.resize_zeroed(n1, n1);
        for (r, c, v) in self.iter() {
            let (k, p) = (r / n2, r % n2);
            let (l, q) = (c / n2, c % n2);
            let val = out.get(k, l) + v * b.get(q, p);
            out.set(k, l, val);
        }
        Ok(())
    }

    /// Weighted block sum `Σ_{ij} W[i,j] · S_(ij)` (dense `n2×n2` out) —
    /// the sparse-Θ form of the `A₂` contraction (App. B.2), `O(nnz)`.
    pub fn weighted_block_sum(&self, w: &Matrix, n1: usize, n2: usize) -> Result<Matrix> {
        let mut out = Matrix::zeros(0, 0);
        self.weighted_block_sum_into(w, n1, n2, &mut out)?;
        Ok(out)
    }

    /// [`SparseMatrix::weighted_block_sum`] into a caller-held output
    /// (see [`SparseMatrix::block_trace_into`]).
    pub fn weighted_block_sum_into(
        &self,
        w: &Matrix,
        n1: usize,
        n2: usize,
        out: &mut Matrix,
    ) -> Result<()> {
        self.check_kron(w, n1, n2, w.rows() == n1)?;
        out.resize_zeroed(n2, n2);
        for (r, c, v) in self.iter() {
            let (i, p) = (r / n2, r % n2);
            let (j, q) = (c / n2, c % n2);
            let val = out.get(p, q) + w.get(i, j) * v;
            out.set(p, q, val);
        }
        Ok(())
    }

    fn check_kron(&self, _m: &Matrix, n1: usize, n2: usize, dims_ok: bool) -> Result<()> {
        if self.n != n1 * n2 || !dims_ok {
            return Err(Error::Shape(format!(
                "sparse kron op: n={} vs n1·n2={}·{}",
                self.n, n1, n2
            )));
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::kron;

    fn rnd(n: usize, seed: u64) -> Matrix {
        let mut state = seed | 1;
        Matrix::from_fn(n, n, |_, _| {
            state ^= state << 13;
            state ^= state >> 7;
            state ^= state << 17;
            (state as f64 / u64::MAX as f64) - 0.5
        })
    }

    #[test]
    fn build_and_densify() {
        let mut b = SparseBuilder::new(4);
        b.add(0, 1, 2.0);
        b.add(0, 1, 3.0); // merge
        b.add(3, 2, -1.0);
        let s = b.build();
        assert_eq!(s.nnz(), 2);
        let d = s.to_dense();
        assert_eq!(d[(0, 1)], 5.0);
        assert_eq!(d[(3, 2)], -1.0);
        assert_eq!(d[(1, 1)], 0.0);
    }

    #[test]
    fn scatter_block_matches_dense_scatter() {
        let mut b = SparseBuilder::new(6);
        let blk = rnd(3, 1);
        let idx = [1usize, 3, 5];
        b.scatter_block(&idx, &blk, 2.0).unwrap();
        let d = b.build().to_dense();
        for (a, &i) in idx.iter().enumerate() {
            for (c, &j) in idx.iter().enumerate() {
                assert!((d[(i, j)] - 2.0 * blk[(a, c)]).abs() < 1e-14);
            }
        }
        assert_eq!(d[(0, 0)], 0.0);
    }

    #[test]
    fn matvec_matches_dense() {
        let dense = rnd(8, 3);
        let mut b = SparseBuilder::new(8);
        for i in 0..8 {
            for j in 0..8 {
                if (i + j) % 3 == 0 {
                    b.add(i, j, dense[(i, j)]);
                }
            }
        }
        let s = b.build();
        let x: Vec<f64> = (0..8).map(|i| i as f64).collect();
        let ys = s.matvec(&x).unwrap();
        let yd = s.to_dense().matvec(&x).unwrap();
        for (p, q) in ys.iter().zip(&yd) {
            assert!((p - q).abs() < 1e-13);
        }
    }

    #[test]
    fn sparse_block_trace_matches_dense() {
        let n1 = 3;
        let n2 = 4;
        let dense = rnd(n1 * n2, 7);
        let mut b = SparseBuilder::new(n1 * n2);
        for i in 0..n1 * n2 {
            for j in 0..n1 * n2 {
                if (i * 13 + j * 7) % 4 == 0 {
                    b.add(i, j, dense[(i, j)]);
                }
            }
        }
        let s = b.build();
        let l2 = rnd(n2, 9);
        let got = s.block_trace(&l2, n1, n2).unwrap();
        let expect = kron::block_trace(&s.to_dense(), &l2, n1, n2).unwrap();
        assert!(got.rel_diff(&expect) < 1e-12);
    }

    #[test]
    fn sparse_weighted_block_sum_matches_dense() {
        let n1 = 4;
        let n2 = 3;
        let dense = rnd(n1 * n2, 17);
        let mut b = SparseBuilder::new(n1 * n2);
        for i in 0..n1 * n2 {
            for j in 0..n1 * n2 {
                if (i + 2 * j) % 3 == 1 {
                    b.add(i, j, dense[(i, j)]);
                }
            }
        }
        let s = b.build();
        let w = rnd(n1, 19);
        let got = s.weighted_block_sum(&w, n1, n2).unwrap();
        let expect = kron::weighted_block_sum(&s.to_dense(), &w, n1, n2).unwrap();
        assert!(got.rel_diff(&expect) < 1e-12);
    }

    #[test]
    fn shape_checks() {
        let s = SparseBuilder::new(6).build();
        assert!(s.block_trace(&Matrix::zeros(4, 4), 2, 3).is_err());
        assert!(s.matvec(&[0.0; 5]).is_err());
    }
}
