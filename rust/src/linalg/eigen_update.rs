//! Incremental symmetric eigendecomposition refresh under low-rank
//! perturbations — the spectral half of delta publishing.
//!
//! Given a cached `A = V·diag(d)·Vᵀ` and a rank-r perturbation
//! `A' = A + Σ_k ρ_k v_k v_kᵀ`, [`refresh_into`] produces the
//! eigendecomposition of `A'` without re-running the `O(n³)` two-stage
//! eigensolver. Each rank-1 term is absorbed by the classic
//! Bunch–Nielsen–Sorensen machinery:
//!
//! 1. project `z = Vᵀv` — the perturbation in eigen-coordinates;
//! 2. **deflate**: components with `|z_i| ≈ 0` keep their eigenpair
//!    verbatim, and clustered eigenvalues are merged by a Givens rotation
//!    on `(z_i, z_j)` (applied to the matching `V` columns) that zeroes
//!    one component exactly;
//! 3. solve the **secular equation** `1 + ρ·Σ ẑ_i²/(d_i − λ) = 0` by
//!    bisection in each interlacing interval (the function is monotone
//!    between poles, so bisection is unconditionally convergent);
//! 4. rebuild the non-deflated eigenvectors from the Löwner formula
//!    `w_k[i] = ẑ_i/(d_i − λ_k)` and push them back to item space with one
//!    GEMM `V' = V·W` — the only super-quadratic step, a single packed
//!    SIMD-dispatched product instead of tridiagonalization + QL + two
//!    back-transforms.
//!
//! The refresh is **self-auditing**: the off-diagonal mass of `WᵀW − I`
//! is measured after every pass (one small GEMM over the non-deflated
//! block) and reported as `drift`. When drift, a degenerate secular
//! interval, or a too-large rank (`r/n` above
//! [`UpdateOptions::max_rank_fraction`]) would compromise the result, the
//! refresh returns [`UpdateOutcome::NeedExact`] and the caller falls back
//! to the exact eigensolver — the registry additionally bounds *accumulated*
//! drift across publishes with its `delta_depth` forced-republish policy.
//!
//! All working storage lives in an [`EigenUpdateScratch`] (including the
//! GEMM pack buffers and the output `values`/`vectors`), so steady-state
//! delta publishing allocates nothing here once warm — the alloc-free
//! region F of `tests/alloc_free.rs`.

use super::matrix::Matrix;
use crate::linalg::matmul::{self, GemmScratch};

/// Relative `|z_i|` threshold below which an eigenpair is deflated
/// (unchanged by the perturbation).
const DEFLATE_TOL: f64 = 1e-13;
/// Relative eigenvalue-gap threshold below which two eigenvalues are
/// treated as a cluster and rotated into a single secular component.
const GAP_TOL: f64 = 1e-13;
/// Bisection iterations per secular root — enough to drive the interval
/// to machine precision from any bracket width.
const BISECT_ITERS: usize = 128;

/// Tuning knobs for [`refresh_into`].
#[derive(Clone, Copy, Debug)]
pub struct UpdateOptions {
    /// Per-pass orthogonality budget: refusal threshold on
    /// `max |WᵀW − I|`. Typical well-conditioned passes measure ~1e-12
    /// (numpy calibration at n ≤ 100); the default leaves three orders of
    /// headroom while still catching pathological clustering.
    pub max_drift: f64,
    /// Refuse when `r > max_rank_fraction · n` — beyond this the r
    /// sequential GEMMs stop beating one exact eigensolve.
    pub max_rank_fraction: f64,
}

impl Default for UpdateOptions {
    fn default() -> Self {
        UpdateOptions { max_drift: 1e-9, max_rank_fraction: 0.25 }
    }
}

/// What the refresh did.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum UpdateOutcome {
    /// Refreshed eigenpairs are in the scratch outputs; `drift` is the
    /// worst per-pass `max |WᵀW − I|` observed (0.0 when every pass
    /// deflated completely).
    Applied {
        /// Worst per-pass orthogonality residual.
        drift: f64,
    },
    /// The perturbation could not be absorbed reliably; the caller must
    /// refactorize exactly. Scratch outputs are unspecified.
    NeedExact {
        /// Static description of the trigger (rank, interval, drift, …).
        reason: &'static str,
    },
}

/// Reusable workspace (and outputs) for [`refresh_into`] — the
/// `SymEigenScratch` pattern: hold one across publishes and the refresh
/// allocates nothing once warm.
#[derive(Default)]
pub struct EigenUpdateScratch {
    /// Perturbation in eigen-coordinates, `z = Vᵀv`.
    z: Vec<f64>,
    /// Gathered perturbation column (item space).
    vcol: Vec<f64>,
    /// Non-deflated eigenvalues / z-components (secular operands).
    dk: Vec<f64>,
    zk: Vec<f64>,
    /// Secular roots.
    lam: Vec<f64>,
    /// Deflation mask and surviving index list.
    keep: Vec<bool>,
    nd: Vec<usize>,
    /// Löwner eigenvectors in z-space (`m×m`).
    w: Matrix,
    /// Gathered / updated non-deflated `V` columns (`n×m`).
    vnd: Matrix,
    vout: Matrix,
    /// `WᵀW` drift probe.
    g: Matrix,
    /// Ascending re-sort permutation + staging.
    order: Vec<usize>,
    dtmp: Vec<f64>,
    vtmp: Matrix,
    /// Pack buffers shared with the GEMM.
    pub gemm: GemmScratch,
    /// Output: refreshed eigenvalues, ascending.
    pub values: Vec<f64>,
    /// Output: refreshed orthonormal eigenvectors, one per column.
    pub vectors: Matrix,
}

impl EigenUpdateScratch {
    pub fn new() -> Self {
        Self::default()
    }
}

/// Refresh the eigendecomposition `(values, vectors)` of a symmetric
/// matrix under `A' = A + Σ_k rhos[k] · vs[:,k] · vs[:,k]ᵀ`. Inputs are
/// borrowed (the cached epoch stays valid); outputs land in
/// `scratch.values` / `scratch.vectors`. `values` must be ascending with
/// `vectors.col(i)` the matching eigenvector — exactly what
/// [`super::eigen::SymEigen`] produces.
pub fn refresh_into(
    values: &[f64],
    vectors: &Matrix,
    rhos: &[f64],
    vs: &Matrix,
    opts: &UpdateOptions,
    scratch: &mut EigenUpdateScratch,
) -> UpdateOutcome {
    let n = values.len();
    let r = rhos.len();
    if vectors.rows() != n || vectors.cols() != n || vs.rows() != n || vs.cols() != r {
        return UpdateOutcome::NeedExact { reason: "shape mismatch" };
    }
    if n == 0 {
        scratch.values.clear();
        scratch.vectors.resize_zeroed(0, 0);
        return UpdateOutcome::Applied { drift: 0.0 };
    }
    if r as f64 > opts.max_rank_fraction * n as f64 {
        return UpdateOutcome::NeedExact { reason: "rank exceeds max_rank_fraction of n" };
    }
    // Work on copies so a mid-sequence refusal leaves the caller's cached
    // decomposition untouched.
    scratch.values.clear();
    scratch.values.extend_from_slice(values);
    scratch.vectors.resize_zeroed(n, n);
    scratch.vectors.copy_from(vectors);
    let mut worst = 0.0f64;
    for k in 0..r {
        scratch.vcol.clear();
        scratch.vcol.extend((0..n).map(|i| vs.get(i, k)));
        match rank_one_pass(n, rhos[k], opts, scratch) {
            Ok(drift) => worst = worst.max(drift),
            Err(reason) => return UpdateOutcome::NeedExact { reason },
        }
    }
    UpdateOutcome::Applied { drift: worst }
}

/// Absorb one `ρ·vvᵀ` term into `scratch.values`/`scratch.vectors`
/// (`scratch.vcol` holds `v`). Returns the pass drift or a refusal reason.
fn rank_one_pass(
    n: usize,
    rho: f64,
    opts: &UpdateOptions,
    sc: &mut EigenUpdateScratch,
) -> std::result::Result<f64, &'static str> {
    // z = Vᵀv, accumulated row-by-row over the contiguous rows of V.
    sc.z.clear();
    sc.z.resize(n, 0.0);
    for i in 0..n {
        let vi = sc.vcol[i];
        if vi != 0.0 {
            matmul::axpy_slice(&mut sc.z, vi, sc.vectors.row(i));
        }
    }
    let znorm2: f64 = sc.z.iter().map(|&x| x * x).sum();
    if !znorm2.is_finite() || !rho.is_finite() {
        return Err("non-finite perturbation");
    }
    let dmax = sc.values.iter().fold(0.0f64, |m, &v| m.max(v.abs()));
    let scale = dmax.max(rho.abs() * znorm2).max(f64::MIN_POSITIVE);
    if rho.abs() * znorm2 <= 1e-15 * scale {
        return Ok(0.0); // numerically a no-op
    }
    let znorm = znorm2.sqrt();

    // Deflation pass 1: tiny z-components keep their eigenpair.
    sc.keep.clear();
    sc.keep.extend(sc.z.iter().map(|&zi| zi.abs() > DEFLATE_TOL * znorm));

    // Deflation pass 2: clustered eigenvalues among survivors — a Givens
    // rotation on (z_i, z_j) zeroes z_i exactly and rotates the matching
    // V columns; column i then stays an eigenvector at d_i ≈ d_j.
    sc.nd.clear();
    sc.nd.extend((0..n).filter(|&i| sc.keep[i]));
    for a in 0..sc.nd.len().saturating_sub(1) {
        let (i, j) = (sc.nd[a], sc.nd[a + 1]);
        if !(sc.keep[i] && sc.keep[j]) {
            continue;
        }
        if (sc.values[j] - sc.values[i]).abs() <= GAP_TOL * scale {
            let rr = sc.z[i].hypot(sc.z[j]);
            let (c, s) = (sc.z[j] / rr, sc.z[i] / rr);
            sc.z[j] = rr;
            sc.z[i] = 0.0;
            for row in 0..n {
                let ci = sc.vectors.get(row, i);
                let cj = sc.vectors.get(row, j);
                sc.vectors.set(row, i, c * ci - s * cj);
                sc.vectors.set(row, j, s * ci + c * cj);
            }
            sc.keep[i] = false;
        }
    }
    sc.nd.clear();
    sc.nd.extend((0..n).filter(|&i| sc.keep[i]));
    let m = sc.nd.len();
    if m == 0 {
        return Ok(0.0); // fully deflated: the perturbation was invisible
    }
    sc.dk.clear();
    sc.dk.extend(sc.nd.iter().map(|&i| sc.values[i]));
    sc.zk.clear();
    sc.zk.extend(sc.nd.iter().map(|&i| sc.z[i]));

    // Secular roots: one per interlacing interval, by bisection (f is
    // monotone between poles: f' = ρ·Σ ẑ²/(d−λ)², the sign of ρ).
    let span = rho.abs() * znorm2;
    sc.lam.clear();
    for k in 0..m {
        let (lo, hi) = if rho > 0.0 {
            (sc.dk[k], if k + 1 < m { sc.dk[k + 1] } else { sc.dk[k] + span })
        } else {
            (if k > 0 { sc.dk[k - 1] } else { sc.dk[0] - span }, sc.dk[k])
        };
        if !(hi > lo) {
            return Err("degenerate secular interval");
        }
        let (mut a, mut b) = (lo, hi);
        for _ in 0..BISECT_ITERS {
            let mid = 0.5 * (a + b);
            if mid <= a || mid >= b {
                break;
            }
            let mut f = 1.0;
            for i in 0..m {
                f += rho * sc.zk[i] * sc.zk[i] / (sc.dk[i] - mid);
            }
            if !f.is_finite() {
                return Err("secular evaluation overflowed");
            }
            // ρ>0: f increases from −∞ to +∞ across the interval;
            // ρ<0: it decreases from +∞ to −∞. Either way the root is on
            // the side where f's sign disagrees with its terminal sign.
            if (f < 0.0) == (rho > 0.0) {
                a = mid;
            } else {
                b = mid;
            }
        }
        sc.lam.push(0.5 * (a + b));
    }

    // Löwner eigenvectors in z-space, one normalized column per root.
    sc.w.resize_zeroed(m, m);
    for k in 0..m {
        let mut norm2 = 0.0;
        for i in 0..m {
            let denom = sc.dk[i] - sc.lam[k];
            if denom == 0.0 {
                return Err("secular root collided with a pole");
            }
            let wi = sc.zk[i] / denom;
            sc.w.set(i, k, wi);
            norm2 += wi * wi;
        }
        if !(norm2.is_finite() && norm2 > 0.0) {
            return Err("degenerate Löwner column");
        }
        let inv = 1.0 / norm2.sqrt();
        for i in 0..m {
            let v = sc.w.get(i, k) * inv;
            sc.w.set(i, k, v);
        }
    }

    // Self-audit: drift = max |WᵀW − I| over the non-deflated block.
    sc.g.resize_zeroed(m, m);
    matmul::gemm_into(sc.g.view_mut(), 1.0, sc.w.view().t(), sc.w.view(), false, &mut sc.gemm);
    let mut drift = 0.0f64;
    for i in 0..m {
        for j in 0..m {
            let want = if i == j { 1.0 } else { 0.0 };
            drift = drift.max((sc.g.get(i, j) - want).abs());
        }
    }
    if !(drift <= opts.max_drift) {
        return Err("orthogonality drift above budget");
    }

    // Push back to item space: V'[:, nd] = V[:, nd]·W (one GEMM), then
    // commit eigenvalues and restore ascending order.
    sc.vnd.resize_zeroed(n, m);
    for (c, &j) in sc.nd.iter().enumerate() {
        for row in 0..n {
            sc.vnd.set(row, c, sc.vectors.get(row, j));
        }
    }
    sc.vout.resize_zeroed(n, m);
    matmul::gemm_into(sc.vout.view_mut(), 1.0, sc.vnd.view(), sc.w.view(), false, &mut sc.gemm);
    for (c, &j) in sc.nd.iter().enumerate() {
        for row in 0..n {
            sc.vectors.set(row, j, sc.vout.get(row, c));
        }
    }
    for (c, &j) in sc.nd.iter().enumerate() {
        sc.values[j] = sc.lam[c];
    }

    sc.order.clear();
    sc.order.extend(0..n);
    let vals = &sc.values;
    sc.order.sort_by(|&i, &j| {
        vals[i].partial_cmp(&vals[j]).unwrap_or(std::cmp::Ordering::Equal)
    });
    if sc.order.iter().enumerate().any(|(pos, &i)| pos != i) {
        sc.dtmp.clear();
        sc.dtmp.extend(sc.order.iter().map(|&i| sc.values[i]));
        sc.values.copy_from_slice(&sc.dtmp);
        sc.vtmp.resize_zeroed(n, n);
        for (new_j, &old_j) in sc.order.iter().enumerate() {
            for row in 0..n {
                sc.vtmp.set(row, new_j, sc.vectors.get(row, old_j));
            }
        }
        sc.vectors.copy_from(&sc.vtmp);
    }
    Ok(drift)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::eigen::SymEigen;
    use crate::linalg::matmul::matmul_nt;

    fn spd(n: usize, seed: u64) -> Matrix {
        let mut state = seed | 1;
        let x = Matrix::from_fn(n, n, |_, _| {
            state ^= state << 13;
            state ^= state >> 7;
            state ^= state << 17;
            (state as f64 / u64::MAX as f64) - 0.5
        });
        let mut g = matmul_nt(&x, &x).unwrap();
        g.add_diag_mut(n as f64 * 0.1);
        g
    }

    fn rand_vectors(n: usize, r: usize, seed: u64, scale: f64) -> Matrix {
        let mut state = seed | 1;
        Matrix::from_fn(n, r, |_, _| {
            state ^= state << 13;
            state ^= state >> 7;
            state ^= state << 17;
            ((state as f64 / u64::MAX as f64) - 0.5) * scale
        })
    }

    /// A + Σ ρ_k v_k v_kᵀ, dense.
    fn perturbed(a: &Matrix, rhos: &[f64], vs: &Matrix) -> Matrix {
        let n = a.rows();
        let mut out = a.clone();
        for (k, &rho) in rhos.iter().enumerate() {
            for i in 0..n {
                for j in 0..n {
                    let v = out.get(i, j) + rho * vs.get(i, k) * vs.get(j, k);
                    out.set(i, j, v);
                }
            }
        }
        out
    }

    /// Assert the scratch outputs eigendecompose `target`: sorted values
    /// match the exact solver, reconstruction matches, columns orthonormal.
    fn assert_refreshed(sc: &EigenUpdateScratch, target: &Matrix, tol: f64, label: &str) {
        let n = target.rows();
        let want = SymEigen::new(target).unwrap();
        let scale = want.values.iter().fold(1.0f64, |m, &v| m.max(v.abs()));
        for i in 0..n {
            assert!(
                (sc.values[i] - want.values[i]).abs() < tol * scale,
                "{label}: value {i}: {} vs {}",
                sc.values[i],
                want.values[i]
            );
        }
        // Reconstruction V·diag(λ)·Vᵀ.
        let mut scaled = sc.vectors.clone();
        for j in 0..n {
            for i in 0..n {
                let v = scaled.get(i, j) * sc.values[j];
                scaled.set(i, j, v);
            }
        }
        let rec = matmul_nt(&scaled, &sc.vectors).unwrap();
        assert!(rec.rel_diff(target) < tol, "{label}: reconstruction {}", rec.rel_diff(target));
        // Orthonormality.
        let gram = matmul_nt(&sc.vectors.transpose(), &sc.vectors.transpose()).unwrap();
        assert!(
            gram.rel_diff(&Matrix::identity(n)) < tol,
            "{label}: orthogonality {}",
            gram.rel_diff(&Matrix::identity(n))
        );
    }

    #[test]
    fn refresh_matches_exact_across_ranks() {
        let opts = UpdateOptions::default();
        let mut sc = EigenUpdateScratch::new();
        for (n, r, seed) in [(12usize, 1usize, 3u64), (16, 2, 5), (40, 8, 7), (24, 4, 9)] {
            let a = spd(n, seed);
            let eig = SymEigen::new(&a).unwrap();
            let vs = rand_vectors(n, r, seed ^ 0xabcd, 0.4);
            // Mixed signs: updates and (mild) downdates in one sequence.
            let rhos: Vec<f64> =
                (0..r).map(|k| if k % 2 == 0 { 1.0 } else { -0.15 }).collect();
            let out = refresh_into(&eig.values, &eig.vectors, &rhos, &vs, &opts, &mut sc);
            let drift = match out {
                UpdateOutcome::Applied { drift } => drift,
                UpdateOutcome::NeedExact { reason } => panic!("n={n} r={r}: {reason}"),
            };
            assert!(drift < 1e-10, "n={n} r={r}: drift {drift}");
            assert_refreshed(&sc, &perturbed(&a, &rhos, &vs), 1e-8, &format!("n={n} r={r}"));
        }
    }

    #[test]
    fn deflation_handles_aligned_and_sparse_perturbations() {
        let opts = UpdateOptions::default();
        let mut sc = EigenUpdateScratch::new();
        // v aligned with an eigenvector: z has one surviving component,
        // everything else deflates, only one eigenvalue moves.
        let a = spd(10, 11);
        let eig = SymEigen::new(&a).unwrap();
        let mut vs = Matrix::zeros(10, 1);
        for i in 0..10 {
            vs.set(i, 0, eig.vectors.get(i, 3));
        }
        let out = refresh_into(&eig.values, &eig.vectors, &[0.8], &vs, &opts, &mut sc);
        assert!(matches!(out, UpdateOutcome::Applied { .. }), "{out:?}");
        assert_refreshed(&sc, &perturbed(&a, &[0.8], &vs), 1e-9, "aligned");

        // Diagonal A with a sparse v: exact zeros in z deflate.
        let a = Matrix::diag(&[1.0, 2.0, 3.0, 4.0, 5.0, 6.0, 7.0, 8.0]);
        let eig = SymEigen::new(&a).unwrap();
        let mut vs = Matrix::zeros(8, 1);
        vs.set(1, 0, 1.3);
        vs.set(5, 0, -0.4);
        let out = refresh_into(&eig.values, &eig.vectors, &[0.9], &vs, &opts, &mut sc);
        assert!(matches!(out, UpdateOutcome::Applied { .. }), "{out:?}");
        assert_refreshed(&sc, &perturbed(&a, &[0.9], &vs), 1e-9, "sparse z");
    }

    #[test]
    fn clustered_spectrum_deflates_by_rotation() {
        // Identity-dominated spectrum: ten equal eigenvalues collapse to a
        // single secular component through the Givens merge.
        let opts = UpdateOptions::default();
        let mut sc = EigenUpdateScratch::new();
        let mut a = Matrix::identity(12);
        a.scale_mut(2.0);
        a.set(0, 0, 3.0);
        let eig = SymEigen::new(&a).unwrap();
        let vs = rand_vectors(12, 1, 21, 1.0);
        let out = refresh_into(&eig.values, &eig.vectors, &[0.5], &vs, &opts, &mut sc);
        assert!(matches!(out, UpdateOutcome::Applied { .. }), "{out:?}");
        assert_refreshed(&sc, &perturbed(&a, &[0.5], &vs), 1e-9, "clustered");
    }

    #[test]
    fn negative_rho_near_singular_still_tracks() {
        // Remove 49% of the smallest eigendirection's mass — legal but
        // close to the edge; the refresh must stay accurate.
        let opts = UpdateOptions::default();
        let mut sc = EigenUpdateScratch::new();
        let a = spd(9, 31);
        let eig = SymEigen::new(&a).unwrap();
        let lam0 = eig.values[0];
        let mut vs = Matrix::zeros(9, 1);
        for i in 0..9 {
            vs.set(i, 0, eig.vectors.get(i, 0) * (lam0 * 0.49).sqrt());
        }
        let out = refresh_into(&eig.values, &eig.vectors, &[-1.0], &vs, &opts, &mut sc);
        assert!(matches!(out, UpdateOutcome::Applied { .. }), "{out:?}");
        assert_refreshed(&sc, &perturbed(&a, &[-1.0], &vs), 1e-8, "near-singular");
        assert!(sc.values[0] > 0.0, "smallest value must stay positive");
    }

    #[test]
    fn refuses_oversized_rank_and_bad_shapes() {
        let opts = UpdateOptions::default();
        let mut sc = EigenUpdateScratch::new();
        let a = spd(8, 41);
        let eig = SymEigen::new(&a).unwrap();
        // r = 3 > 0.25·8: must refuse rather than grind through.
        let vs = rand_vectors(8, 3, 43, 0.3);
        let out = refresh_into(&eig.values, &eig.vectors, &[1.0, 1.0, 1.0], &vs, &opts, &mut sc);
        assert!(matches!(out, UpdateOutcome::NeedExact { .. }), "{out:?}");
        // Mismatched vs height.
        let bad = rand_vectors(7, 1, 45, 0.3);
        let out = refresh_into(&eig.values, &eig.vectors, &[1.0], &bad, &opts, &mut sc);
        assert!(matches!(out, UpdateOutcome::NeedExact { .. }), "{out:?}");
        // Non-finite perturbation.
        let mut nan = rand_vectors(8, 1, 47, 0.3);
        nan.set(2, 0, f64::NAN);
        let out = refresh_into(&eig.values, &eig.vectors, &[1.0], &nan, &opts, &mut sc);
        assert!(matches!(out, UpdateOutcome::NeedExact { .. }), "{out:?}");
    }

    #[test]
    fn repeated_refreshes_are_scratch_stable() {
        // A long chain of alternating rank-1 updates/downdates through one
        // scratch must track the exact decomposition of the running matrix.
        let opts = UpdateOptions::default();
        let mut sc = EigenUpdateScratch::new();
        let mut a = spd(14, 51);
        let mut eig = SymEigen::new(&a).unwrap();
        for step in 0..20 {
            let rho = if step % 3 == 2 { -0.05 } else { 0.6 };
            let vs = rand_vectors(14, 1, 100 + step, 0.35);
            let out = refresh_into(&eig.values, &eig.vectors, &[rho], &vs, &opts, &mut sc);
            assert!(matches!(out, UpdateOutcome::Applied { .. }), "step {step}: {out:?}");
            a = perturbed(&a, &[rho], &vs);
            eig = SymEigen { values: sc.values.clone(), vectors: sc.vectors.clone() };
        }
        assert_refreshed(&sc, &a, 1e-7, "20-step chain");
    }
}
