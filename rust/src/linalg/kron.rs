//! Kronecker-product algebra — the mathematical core of KronDPP.
//!
//! Conventions: for `A (N₁×N₁)`, `B (N₂×N₂)`, the product `A ⊗ B` is the
//! `N₁N₂ × N₁N₂` block matrix whose `(i,j)` block (written `M_(ij)` as in
//! the paper) is `a_ij · B`. Item index `t ∈ {0..N₁N₂}` factors as
//! `t = i·N₂ + r` with `i` the block (sub-kernel-1) index and `r` the
//! within-block (sub-kernel-2) index.
//!
//! Everything the paper's Prop. 2.1–2.4 and App. A/B need is here:
//! the product itself, matvecs that never materialize `A ⊗ B`, block
//! extraction, partial traces `Tr₁`/`Tr₂` (Def. 2.3), and the *scaled*
//! partial traces `Tr₁((I⊗S₂)M)` / `Tr₂((S₁⊗I)M)` that appear in the
//! KRK-Picard updates (Prop. 3.1).

use super::matrix::Matrix;
use crate::error::{Error, Result};
use crate::linalg::matmul::{self, dot};

/// Dense Kronecker product `A ⊗ B` (Prop. 2.1 notation): the block matrix
/// with `(i,j)` block `a_ij·B`. `O(N²)` time and space for the `N×N`
/// result (`N = pr`), so this is reserved for sub-kernel-sized operands
/// and tests — the library's DPP operations never materialize `L₁ ⊗ L₂`.
///
/// ```
/// use krondpp::linalg::{kron, Matrix};
/// let a = Matrix::from_rows(&[&[1.0, 2.0], &[3.0, 4.0]]).unwrap();
/// let k = kron::kron(&a, &Matrix::identity(2));
/// assert_eq!(k.shape(), (4, 4));
/// assert_eq!(k[(0, 2)], 2.0); // block (0,1) = 2·I
/// assert_eq!(k[(2, 0)], 3.0); // block (1,0) = 3·I
/// assert_eq!(k[(0, 1)], 0.0);
/// ```
pub fn kron(a: &Matrix, b: &Matrix) -> Matrix {
    let (p, q) = a.shape();
    let (r, s) = b.shape();
    let mut out = Matrix::zeros(p * r, q * s);
    for i in 0..p {
        for j in 0..q {
            let aij = a.get(i, j);
            if aij == 0.0 {
                continue;
            }
            for bi in 0..r {
                let brow = b.row(bi);
                let orow = out.row_mut(i * r + bi);
                let dst = &mut orow[j * s..(j + 1) * s];
                for (d, &bv) in dst.iter_mut().zip(brow) {
                    *d = aij * bv;
                }
            }
        }
    }
    out
}

/// Three-factor Kronecker product `A ⊗ B ⊗ C` — the paper's m = 3 KronDPP
/// kernel (§2, associativity of ⊗). `O(N²)` for the `N = n₁n₂n₃` result;
/// tests/small-N only, like [`kron`].
///
/// ```
/// use krondpp::linalg::{kron, Matrix};
/// let k = kron::kron3(
///     &Matrix::diag(&[2.0]),
///     &Matrix::diag(&[3.0, 5.0]),
///     &Matrix::identity(2),
/// );
/// assert_eq!(k.shape(), (4, 4));
/// assert_eq!(k[(0, 0)], 6.0);  // 2·3·1
/// assert_eq!(k[(2, 2)], 10.0); // 2·5·1
/// ```
pub fn kron3(a: &Matrix, b: &Matrix, c: &Matrix) -> Matrix {
    kron(&kron(a, b), c)
}

/// `y = (A ⊗ B)·x` without forming the product (Prop. 2.1(ii)): reshape
/// `x` to an `N₁×N₂` matrix `X` (row-major) and compute `A · X · Bᵀ` —
/// `O(N(N₁+N₂)) = O(N^{3/2})` for square factors instead of `O(N²)`.
///
/// ```
/// use krondpp::linalg::{kron, Matrix};
/// let a = Matrix::from_rows(&[&[1.0, 1.0], &[0.0, 2.0]]).unwrap();
/// let b = Matrix::identity(3);
/// let x: Vec<f64> = (0..6).map(|i| i as f64).collect();
/// let fast = kron::kron_matvec(&a, &b, &x).unwrap();
/// let dense = kron::kron(&a, &b).matvec(&x).unwrap();
/// for (p, q) in fast.iter().zip(&dense) {
///     assert!((p - q).abs() < 1e-12);
/// }
/// ```
pub fn kron_matvec(a: &Matrix, b: &Matrix, x: &[f64]) -> Result<Vec<f64>> {
    let n1 = a.rows();
    let n2 = b.rows();
    if x.len() != a.cols() * b.cols() {
        return Err(Error::Shape(format!(
            "kron_matvec: ({}x{})⊗({}x{}) times len {}",
            a.rows(),
            a.cols(),
            b.rows(),
            b.cols(),
            x.len()
        )));
    }
    let xm = Matrix::from_vec(a.cols(), b.cols(), x.to_vec())?;
    let ax = matmul::matmul(a, &xm)?;
    let axbt = matmul::matmul_nt(&ax, b)?;
    debug_assert_eq!(axbt.shape(), (n1, n2));
    Ok(axbt.into_vec())
}

/// Extract block `M_(ij)` (size `n2×n2`) of an `n1·n2`-square matrix —
/// the paper's `M_(ij)` block notation (§2). `O(n2²)`.
///
/// ```
/// use krondpp::linalg::{kron, Matrix};
/// let a = Matrix::from_rows(&[&[1.0, 2.0], &[3.0, 4.0]]).unwrap();
/// let b = Matrix::diag(&[5.0, 6.0]);
/// let m = kron::kron(&a, &b);
/// // Block (1,0) of A⊗B is a_10·B = 3·B.
/// let blk = kron::block(&m, 1, 0, 2);
/// assert_eq!(blk[(0, 0)], 15.0);
/// assert_eq!(blk[(1, 1)], 18.0);
/// ```
pub fn block(m: &Matrix, i: usize, j: usize, n2: usize) -> Matrix {
    m.block(i * n2, j * n2, n2, n2)
        .expect("kron::block: index within range by contract")
}

/// Partial trace `Tr₁(M)[i,j] = Tr(M_(ij))` (Def. 2.3) — an `n1×n1`
/// matrix, `O(N²)` in one pass over `M`. For a Kronecker product,
/// `Tr₁(A ⊗ B) = Tr(B)·A` (Prop. 2.4).
///
/// ```
/// use krondpp::linalg::{kron, Matrix};
/// let a = Matrix::from_rows(&[&[1.0, 2.0], &[3.0, 4.0]]).unwrap();
/// let b = Matrix::diag(&[5.0, 6.0]); // Tr(B) = 11
/// let m = kron::kron(&a, &b);
/// let t1 = kron::partial_trace_1(&m, 2, 2).unwrap();
/// assert!(t1.rel_diff(&a.scaled(11.0)) < 1e-12);
/// ```
pub fn partial_trace_1(m: &Matrix, n1: usize, n2: usize) -> Result<Matrix> {
    check_kron_dims(m, n1, n2)?;
    let n = n1 * n2;
    let data = m.as_slice();
    let mut out = Matrix::zeros(n1, n1);
    for i in 0..n1 {
        for j in 0..n1 {
            let mut t = 0.0;
            for r in 0..n2 {
                t += data[(i * n2 + r) * n + (j * n2 + r)];
            }
            out.set(i, j, t);
        }
    }
    Ok(out)
}

/// Partial trace `Tr₂(M) = Σ_i M_(ii)` (Def. 2.3) — an `n2×n2` matrix,
/// `O(N·n₂)` (it touches only the diagonal blocks). For a Kronecker
/// product, `Tr₂(A ⊗ B) = Tr(A)·B` (Prop. 2.4).
///
/// ```
/// use krondpp::linalg::{kron, Matrix};
/// let a = Matrix::from_rows(&[&[1.0, 2.0], &[3.0, 4.0]]).unwrap(); // Tr = 5
/// let b = Matrix::diag(&[5.0, 6.0]);
/// let m = kron::kron(&a, &b);
/// let t2 = kron::partial_trace_2(&m, 2, 2).unwrap();
/// assert!(t2.rel_diff(&b.scaled(5.0)) < 1e-12);
/// ```
pub fn partial_trace_2(m: &Matrix, n1: usize, n2: usize) -> Result<Matrix> {
    check_kron_dims(m, n1, n2)?;
    let n = n1 * n2;
    let data = m.as_slice();
    let mut out = Matrix::zeros(n2, n2);
    for i in 0..n1 {
        for r in 0..n2 {
            let src = &data[(i * n2 + r) * n + i * n2..(i * n2 + r) * n + (i + 1) * n2];
            let dst = out.row_mut(r);
            for (d, s) in dst.iter_mut().zip(src) {
                *d += s;
            }
        }
    }
    Ok(out)
}

/// Scaled partial trace `Tr₁((I ⊗ S₂) M)[i,j] = Tr(S₂ · M_(ij))`
/// = `Σ_{p,q} S₂[p,q] · M_(ij)[q,p]` — the contraction at the heart of the
/// `L₁` update of KRK-Picard (Prop. 3.1 / App. B.1, with `S₂ = L₂⁻¹` or
/// `L₂`). `O(N₁² N₂²)` = `O(N²)` in one pass over `M`, multithreaded above
/// ~4M multiply-adds; never materializes `I ⊗ S₂`.
///
/// ```
/// use krondpp::linalg::{kron, matmul, Matrix};
/// let m = Matrix::from_fn(6, 6, |i, j| (i * 6 + j) as f64);
/// let s2 = Matrix::from_rows(&[&[2.0, 1.0], &[1.0, 0.5]]).unwrap();
/// let fast = kron::tr1_scaled(&m, &s2, 3, 2).unwrap();
/// // Definition: Tr₁ of the dense product (I ⊗ S₂)·M.
/// let dense = matmul::matmul(&kron::kron(&Matrix::identity(3), &s2), &m).unwrap();
/// let want = kron::partial_trace_1(&dense, 3, 2).unwrap();
/// assert!(fast.rel_diff(&want) < 1e-12);
/// ```
pub fn tr1_scaled(m: &Matrix, s2: &Matrix, n1: usize, n2: usize) -> Result<Matrix> {
    let mut out = Matrix::zeros(0, 0);
    tr1_scaled_into(m, s2, n1, n2, &mut out)?;
    Ok(out)
}

/// [`tr1_scaled`] into a caller-held output — the allocation-free form
/// behind the KRK-Picard hot loop (the transposed `S₂` staging buffer is
/// a reused thread-local).
pub fn tr1_scaled_into(
    m: &Matrix,
    s2: &Matrix,
    n1: usize,
    n2: usize,
    out: &mut Matrix,
) -> Result<()> {
    check_kron_dims(m, n1, n2)?;
    if s2.shape() != (n2, n2) {
        return Err(Error::Shape("tr1_scaled: S2 shape mismatch".into()));
    }
    let n = n1 * n2;
    let data = m.as_slice();
    out.resize_zeroed(n1, n1);
    // Transposed S2 (thread-local staging) so inner loops stream rows of
    // both operands: Tr(S2·B) = Σ_{p,r} S2[p,r]·B[r,p]
    //                         = Σ_r dot(B[r,:], S2ᵀ[r,:]).
    with_transposed(s2, |s2t| {
        let do_row = |i: usize, orow: &mut [f64]| {
            for (j, oj) in orow.iter_mut().enumerate() {
                let mut t = 0.0;
                for r in 0..n2 {
                    let brow =
                        &data[(i * n2 + r) * n + j * n2..(i * n2 + r) * n + (j + 1) * n2];
                    t += dot(brow, s2t.row(r));
                }
                *oj = t;
            }
        };
        // Parallel over block rows when large.
        if n1 * n1 * n2 * n2 > 1 << 22 {
            let nthreads = matmul::available_threads();
            let band = n1.div_ceil(nthreads).max(1);
            let out_slice = out.as_mut_slice();
            std::thread::scope(|s| {
                let mut rest = out_slice;
                let mut start = 0usize;
                let mut handles = Vec::new();
                while start < n1 {
                    let len = band.min(n1 - start);
                    let (chunk, tail) = rest.split_at_mut(len * n1);
                    rest = tail;
                    let lo = start;
                    let do_row = &do_row;
                    handles.push(s.spawn(move || {
                        for (k, i) in (lo..lo + len).enumerate() {
                            do_row(i, &mut chunk[k * n1..(k + 1) * n1]);
                        }
                    }));
                    start += len;
                }
                for h in handles {
                    h.join().expect("tr1_scaled worker panicked");
                }
            });
        } else {
            for i in 0..n1 {
                do_row(i, out.row_mut(i));
            }
        }
    });
    Ok(())
}

/// Run `f` with a thread-local transposed copy of `s` (allocation-free
/// once the staging buffer has grown to the working size).
fn with_transposed<R>(s: &Matrix, f: impl FnOnce(&Matrix) -> R) -> R {
    use std::cell::RefCell;
    thread_local! {
        static BUF: RefCell<Matrix> = RefCell::new(Matrix::zeros(0, 0));
    }
    BUF.with(|b| {
        let mut b = b.borrow_mut();
        s.transpose_into(&mut b);
        f(&b)
    })
}

/// Scaled partial trace `Tr₂((S₁ ⊗ I) M) = Σ_{i,l} S₁[i,l] · M_(li)` — the
/// contraction of the KRK-Picard `L₂` update (App. B.2, with `S₁ = L₁⁻¹`).
/// `O(N₁² N₂²)` = `O(N²)`; never materializes `S₁ ⊗ I`.
///
/// ```
/// use krondpp::linalg::{kron, matmul, Matrix};
/// let m = Matrix::from_fn(6, 6, |i, j| ((i + 2 * j) % 5) as f64);
/// let s1 = Matrix::from_rows(&[&[1.0, 2.0], &[0.0, 1.0]]).unwrap();
/// let fast = kron::tr2_scaled(&m, &s1, 2, 3).unwrap();
/// let dense = matmul::matmul(&kron::kron(&s1, &Matrix::identity(3)), &m).unwrap();
/// let want = kron::partial_trace_2(&dense, 2, 3).unwrap();
/// assert!(fast.rel_diff(&want) < 1e-12);
/// ```
pub fn tr2_scaled(m: &Matrix, s1: &Matrix, n1: usize, n2: usize) -> Result<Matrix> {
    let mut out = Matrix::zeros(0, 0);
    tr2_scaled_into(m, s1, n1, n2, &mut out)?;
    Ok(out)
}

/// [`tr2_scaled`] into a caller-held output (allocation-free once `out`
/// has capacity).
pub fn tr2_scaled_into(
    m: &Matrix,
    s1: &Matrix,
    n1: usize,
    n2: usize,
    out: &mut Matrix,
) -> Result<()> {
    check_kron_dims(m, n1, n2)?;
    if s1.shape() != (n1, n1) {
        return Err(Error::Shape("tr2_scaled: S1 shape mismatch".into()));
    }
    let n = n1 * n2;
    let data = m.as_slice();
    out.resize_zeroed(n2, n2);
    for i in 0..n1 {
        for l in 0..n1 {
            let w = s1.get(i, l);
            if w == 0.0 {
                continue;
            }
            // out += w * M_(li)
            for r in 0..n2 {
                let src = &data[(l * n2 + r) * n + i * n2..(l * n2 + r) * n + (i + 1) * n2];
                let dst = out.row_mut(r);
                matmul::axpy_slice(dst, w, src);
            }
        }
    }
    Ok(())
}

/// Weighted block sum `Σ_{i,j} W[i,j] · M_(ij)` (an `n2×n2` matrix) — the
/// `A₂` contraction of App. B.2 with `W = L₁`. `O(N²)` (skipping zero
/// weights). For symmetric `M` and `W`, equals [`tr2_scaled`].
///
/// ```
/// use krondpp::linalg::{kron, Matrix};
/// let m = Matrix::from_fn(6, 6, |i, j| (i as f64 - j as f64).abs());
/// // W = I sums the diagonal blocks: exactly Tr₂ (Def. 2.3).
/// let summed = kron::weighted_block_sum(&m, &Matrix::identity(2), 2, 3).unwrap();
/// let tr2 = kron::partial_trace_2(&m, 2, 3).unwrap();
/// assert!(summed.rel_diff(&tr2) < 1e-13);
/// ```
pub fn weighted_block_sum(m: &Matrix, w: &Matrix, n1: usize, n2: usize) -> Result<Matrix> {
    let mut out = Matrix::zeros(0, 0);
    weighted_block_sum_into(m, w, n1, n2, &mut out)?;
    Ok(out)
}

/// [`weighted_block_sum`] into a caller-held output (allocation-free once
/// `out` has capacity).
pub fn weighted_block_sum_into(
    m: &Matrix,
    w: &Matrix,
    n1: usize,
    n2: usize,
    out: &mut Matrix,
) -> Result<()> {
    check_kron_dims(m, n1, n2)?;
    if w.shape() != (n1, n1) {
        return Err(Error::Shape("weighted_block_sum: W shape mismatch".into()));
    }
    let n = n1 * n2;
    let data = m.as_slice();
    out.resize_zeroed(n2, n2);
    for i in 0..n1 {
        for j in 0..n1 {
            let wij = w.get(i, j);
            if wij == 0.0 {
                continue;
            }
            for r in 0..n2 {
                let src = &data[(i * n2 + r) * n + j * n2..(i * n2 + r) * n + (j + 1) * n2];
                matmul::axpy_slice(out.row_mut(r), wij, src);
            }
        }
    }
    Ok(())
}

/// Block-trace contraction `A[k,l] = Tr(M_(kl) · B)` for all `(k,l)` — the
/// `A₁` matrix of App. B.1 with `M = Θ`, `B = L₂`. Identical math to
/// [`tr1_scaled`] with `S₂ = B` (`O(N²)`); kept as a named alias for
/// readability at call sites mirroring the paper.
///
/// ```
/// use krondpp::linalg::{kron, Matrix};
/// let m = Matrix::from_fn(6, 6, |i, j| (i * j) as f64);
/// let b = Matrix::diag(&[1.0, 3.0]);
/// let a1 = kron::block_trace(&m, &b, 3, 2).unwrap();
/// assert!(a1.rel_diff(&kron::tr1_scaled(&m, &b, 3, 2).unwrap()) < 1e-15);
/// ```
pub fn block_trace(m: &Matrix, b: &Matrix, n1: usize, n2: usize) -> Result<Matrix> {
    tr1_scaled(m, b, n1, n2)
}

/// [`block_trace`] into a caller-held output (alias of
/// [`tr1_scaled_into`], kept for call-site readability).
pub fn block_trace_into(
    m: &Matrix,
    b: &Matrix,
    n1: usize,
    n2: usize,
    out: &mut Matrix,
) -> Result<()> {
    tr1_scaled_into(m, b, n1, n2, out)
}

/// Mixed weighted partial trace over a three-factor index split
/// `t = (i, j, r)` with `i ∈ n1`, `j ∈ n2`, `r ∈ n3`:
///
/// `H[j', j] = Σ_{i,i',r,r'} W1[i,i'] · W3[r,r'] · M[(i',j',r'), (i,j,r)]`
///
/// — the middle-factor contraction of the m = 3 KRK-Picard update
/// (§3.1.1 multiblock generalization; see [`crate::learn::krk3`]). One
/// pass over `M`, `O(N²)`.
///
/// ```
/// use krondpp::linalg::{kron, Matrix};
/// // With W₁ = I, W₃ = I and M = A⊗B⊗C this reduces to Tr(A)·Tr(C)·B.
/// let a = Matrix::diag(&[1.0, 2.0]);                             // Tr = 3
/// let b = Matrix::from_rows(&[&[1.0, 4.0], &[4.0, 2.0]]).unwrap();
/// let c = Matrix::diag(&[2.0, 3.0]);                             // Tr = 5
/// let m = kron::kron3(&a, &b, &c);
/// let h = kron::mixed_weighted_trace(
///     &m, &Matrix::identity(2), &Matrix::identity(2), 2, 2, 2,
/// ).unwrap();
/// assert!(h.rel_diff(&b.scaled(15.0)) < 1e-12);
/// ```
pub fn mixed_weighted_trace(
    m: &Matrix,
    w1: &Matrix,
    w3: &Matrix,
    n1: usize,
    n2: usize,
    n3: usize,
) -> Result<Matrix> {
    let n = n1 * n2 * n3;
    if m.shape() != (n, n) {
        return Err(Error::Shape(format!(
            "mixed_weighted_trace: {}x{} vs n1·n2·n3 = {n}",
            m.rows(),
            m.cols()
        )));
    }
    if w1.shape() != (n1, n1) || w3.shape() != (n3, n3) {
        return Err(Error::Shape("mixed_weighted_trace: weight shape mismatch".into()));
    }
    let data = m.as_slice();
    let mut h = Matrix::zeros(n2, n2);
    for ip in 0..n1 {
        for jp in 0..n2 {
            for i in 0..n1 {
                let w1v = w1.get(i, ip);
                if w1v == 0.0 {
                    continue;
                }
                for j in 0..n2 {
                    // accumulate Σ_{r',r} W3[r,r']·M[(i',j',r'),(i,j,r)]
                    let mut acc = 0.0;
                    for rp in 0..n3 {
                        let row = (ip * n2 + jp) * n3 + rp;
                        let base = row * n + (i * n2 + j) * n3;
                        let mrow = &data[base..base + n3];
                        // Σ_r W3[r, r']·mrow[r] — use column of W3.
                        let mut inner = 0.0;
                        for (r, &mv) in mrow.iter().enumerate() {
                            inner += w3.get(r, rp) * mv;
                        }
                        acc += inner;
                    }
                    let v = h.get(jp, j) + w1v * acc;
                    h.set(jp, j, v);
                }
            }
        }
    }
    Ok(h)
}

/// Eigendecomposition of `A ⊗ B` from sub-decompositions (Cor. 2.2):
/// given eigenvalues of `A` and `B`, the spectrum of `A ⊗ B` is the outer
/// product `λ_i(A)·λ_j(B)`, in item order `t = i·N₂ + j`. `O(N)` — this is
/// why KronDPP sampling preprocessing is `O(N^{3/2})` (§4): only the
/// sub-kernels are ever eigendecomposed.
///
/// ```
/// use krondpp::linalg::kron::kron_eigenvalues;
/// assert_eq!(kron_eigenvalues(&[1.0, 2.0], &[3.0, 4.0]), vec![3.0, 4.0, 6.0, 8.0]);
/// ```
pub fn kron_eigenvalues(da: &[f64], db: &[f64]) -> Vec<f64> {
    let mut out = Vec::with_capacity(da.len() * db.len());
    for &a in da {
        for &b in db {
            out.push(a * b);
        }
    }
    out
}

/// Entry `(row, col)` of `P_A ⊗ P_B` without forming it — `O(1)` per entry
/// (the index split `t = i·N₂ + r` of §2), used for `L_Y` principal
/// submatrices in `O(κ²)`.
///
/// ```
/// use krondpp::linalg::{kron, Matrix};
/// let a = Matrix::from_rows(&[&[1.0, 2.0], &[3.0, 4.0]]).unwrap();
/// let b = Matrix::diag(&[5.0, 7.0]);
/// let dense = kron::kron(&a, &b);
/// assert_eq!(kron::kron_entry(&a, &b, 2, 3, 1), dense[(3, 1)]);
/// assert_eq!(kron::kron_entry(&a, &b, 2, 2, 0), dense[(2, 0)]);
/// ```
#[inline(always)]
pub fn kron_entry(pa: &Matrix, pb: &Matrix, n2: usize, row: usize, col: usize) -> f64 {
    pa.get(row / n2, col / n2) * pb.get(row % n2, col % n2)
}

/// Column `col` of `P_A ⊗ P_B` (an eigenvector of the Kron kernel) in
/// `O(N)` — the §4 claim that `k` eigenvectors cost `O(kN)`, which keeps
/// phase 2 of sampling independent of the `O(N³)` dense eigenvector cost.
///
/// ```
/// use krondpp::linalg::{kron, Matrix};
/// let a = Matrix::from_rows(&[&[1.0, 2.0], &[3.0, 4.0]]).unwrap();
/// let b = Matrix::diag(&[5.0, 7.0]);
/// let col = kron::kron_column(&a, &b, 2, 3);
/// let dense = kron::kron(&a, &b).col(3);
/// assert_eq!(col, dense);
/// ```
pub fn kron_column(pa: &Matrix, pb: &Matrix, n2: usize, col: usize) -> Vec<f64> {
    let n1 = pa.rows();
    let (ca, cb) = (col / n2, col % n2);
    let mut out = Vec::with_capacity(n1 * n2);
    for i in 0..n1 {
        let a = pa.get(i, ca);
        for r in 0..n2 {
            out.push(a * pb.get(r, cb));
        }
    }
    out
}

fn check_kron_dims(m: &Matrix, n1: usize, n2: usize) -> Result<()> {
    if m.shape() != (n1 * n2, n1 * n2) {
        return Err(Error::Shape(format!(
            "expected {}x{} (n1={n1} · n2={n2}), got {}x{}",
            n1 * n2,
            n1 * n2,
            m.rows(),
            m.cols()
        )));
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::matmul::matmul;

    fn rnd(n: usize, seed: u64) -> Matrix {
        let mut state = seed | 1;
        Matrix::from_fn(n, n, |_, _| {
            state ^= state << 13;
            state ^= state >> 7;
            state ^= state << 17;
            (state as f64 / u64::MAX as f64) - 0.5
        })
    }

    #[test]
    fn kron_small_manual() {
        let a = Matrix::from_rows(&[&[1.0, 2.0], &[3.0, 4.0]]).unwrap();
        let b = Matrix::identity(2);
        let k = kron(&a, &b);
        assert_eq!(k.shape(), (4, 4));
        assert_eq!(k[(0, 0)], 1.0);
        assert_eq!(k[(0, 2)], 2.0);
        assert_eq!(k[(1, 3)], 2.0);
        assert_eq!(k[(2, 0)], 3.0);
        assert_eq!(k[(3, 3)], 4.0);
        assert_eq!(k[(0, 1)], 0.0);
    }

    #[test]
    fn mixed_product_property() {
        // Prop 2.1(iii): (A⊗B)(C⊗D) = (AC)⊗(BD)
        let a = rnd(3, 1);
        let b = rnd(4, 2);
        let c = rnd(3, 3);
        let d = rnd(4, 4);
        let lhs = matmul(&kron(&a, &b), &kron(&c, &d)).unwrap();
        let rhs = kron(&matmul(&a, &c).unwrap(), &matmul(&b, &d).unwrap());
        assert!(lhs.rel_diff(&rhs) < 1e-12);
    }

    #[test]
    fn kron_matvec_matches_dense() {
        let a = rnd(3, 5);
        let b = rnd(4, 6);
        let x: Vec<f64> = (0..12).map(|i| (i as f64).sin()).collect();
        let dense = kron(&a, &b).matvec(&x).unwrap();
        let fast = kron_matvec(&a, &b, &x).unwrap();
        for (p, q) in dense.iter().zip(&fast) {
            assert!((p - q).abs() < 1e-12);
        }
    }

    #[test]
    fn partial_traces_of_kron_product() {
        // Tr1(A⊗B) = Tr(B)·A  and  Tr2(A⊗B) = Tr(A)·B
        let a = rnd(3, 7);
        let b = rnd(5, 8);
        let m = kron(&a, &b);
        let t1 = partial_trace_1(&m, 3, 5).unwrap();
        assert!(t1.rel_diff(&a.scaled(b.trace())) < 1e-12);
        let t2 = partial_trace_2(&m, 3, 5).unwrap();
        assert!(t2.rel_diff(&b.scaled(a.trace())) < 1e-12);
    }

    #[test]
    fn partial_trace_preserves_trace() {
        let m = rnd(12, 9);
        let t1 = partial_trace_1(&m, 3, 4).unwrap();
        let t2 = partial_trace_2(&m, 3, 4).unwrap();
        assert!((t1.trace() - m.trace()).abs() < 1e-12);
        assert!((t2.trace() - m.trace()).abs() < 1e-12);
    }

    #[test]
    fn tr1_scaled_matches_dense_formula() {
        // Tr1((I⊗S2)·M) computed densely vs contraction.
        let n1 = 3;
        let n2 = 4;
        let m = rnd(n1 * n2, 11);
        let s2 = rnd(n2, 12);
        let dense = matmul(&kron(&Matrix::identity(n1), &s2), &m).unwrap();
        let expect = partial_trace_1(&dense, n1, n2).unwrap();
        let got = tr1_scaled(&m, &s2, n1, n2).unwrap();
        assert!(got.rel_diff(&expect) < 1e-12);
    }

    #[test]
    fn tr2_scaled_matches_dense_formula() {
        let n1 = 4;
        let n2 = 3;
        let m = rnd(n1 * n2, 13);
        let s1 = rnd(n1, 14);
        let dense = matmul(&kron(&s1, &Matrix::identity(n2)), &m).unwrap();
        let expect = partial_trace_2(&dense, n1, n2).unwrap();
        let got = tr2_scaled(&m, &s1, n1, n2).unwrap();
        assert!(got.rel_diff(&expect) < 1e-12);
    }

    #[test]
    fn weighted_block_sum_symmetric_equals_tr2() {
        let n1 = 3;
        let n2 = 4;
        let mut m = rnd(n1 * n2, 15);
        m.symmetrize_mut();
        let mut w = rnd(n1, 16);
        w.symmetrize_mut();
        let a = weighted_block_sum(&m, &w, n1, n2).unwrap();
        let b = tr2_scaled(&m, &w, n1, n2).unwrap();
        assert!(a.rel_diff(&b) < 1e-12);
    }

    #[test]
    fn kron_eigen_structure() {
        // Cor. 2.2 spectrum check via dense eigendecomposition.
        use crate::linalg::eigen::SymEigen;
        let mut a = rnd(3, 17);
        a.symmetrize_mut();
        let mut b = rnd(4, 18);
        b.symmetrize_mut();
        let ea = SymEigen::new(&a).unwrap();
        let eb = SymEigen::new(&b).unwrap();
        let mut kron_eigs = kron_eigenvalues(&ea.values, &eb.values);
        kron_eigs.sort_by(|x, y| x.partial_cmp(y).unwrap());
        let dense = SymEigen::new(&kron(&a, &b)).unwrap();
        for (p, q) in kron_eigs.iter().zip(&dense.values) {
            assert!((p - q).abs() < 1e-10, "{p} vs {q}");
        }
    }

    #[test]
    fn kron_column_matches_dense_column() {
        let a = rnd(3, 19);
        let b = rnd(4, 20);
        let dense = kron(&a, &b);
        for col in [0usize, 5, 11] {
            let fast = kron_column(&a, &b, 4, col);
            let slow = dense.col(col);
            for (p, q) in fast.iter().zip(&slow) {
                assert!((p - q).abs() < 1e-14);
            }
        }
        assert_eq!(kron_entry(&a, &b, 4, 7, 10), dense[(7, 10)]);
    }

    #[test]
    fn block_extraction() {
        let a = rnd(3, 21);
        let b = rnd(4, 22);
        let m = kron(&a, &b);
        for i in 0..3 {
            for j in 0..3 {
                let blk = block(&m, i, j, 4);
                assert!(blk.rel_diff(&b.scaled(a.get(i, j))) < 1e-13);
            }
        }
    }

    #[test]
    fn into_variants_reuse_buffers() {
        let n1 = 3;
        let n2 = 4;
        let m = rnd(n1 * n2, 41);
        let s2 = rnd(n2, 42);
        let s1 = rnd(n1, 43);
        let mut out = Matrix::zeros(7, 7); // wrong shape: must be resized
        tr1_scaled_into(&m, &s2, n1, n2, &mut out).unwrap();
        assert!(out.rel_diff(&tr1_scaled(&m, &s2, n1, n2).unwrap()) < 1e-15);
        tr2_scaled_into(&m, &s1, n1, n2, &mut out).unwrap();
        assert!(out.rel_diff(&tr2_scaled(&m, &s1, n1, n2).unwrap()) < 1e-15);
        weighted_block_sum_into(&m, &s1, n1, n2, &mut out).unwrap();
        assert!(out.rel_diff(&weighted_block_sum(&m, &s1, n1, n2).unwrap()) < 1e-15);
        block_trace_into(&m, &s2, n1, n2, &mut out).unwrap();
        assert!(out.rel_diff(&block_trace(&m, &s2, n1, n2).unwrap()) < 1e-15);
    }

    #[test]
    fn dim_checks() {
        let m = Matrix::zeros(6, 6);
        assert!(partial_trace_1(&m, 2, 4).is_err());
        assert!(tr1_scaled(&m, &Matrix::zeros(3, 3), 2, 3).is_ok());
        assert!(tr1_scaled(&m, &Matrix::zeros(2, 2), 2, 3).is_err());
    }

    #[test]
    fn kron3_shape_and_values() {
        let a = Matrix::diag(&[2.0]);
        let b = Matrix::diag(&[3.0, 5.0]);
        let c = Matrix::identity(2);
        let k = kron3(&a, &b, &c);
        assert_eq!(k.shape(), (4, 4));
        assert_eq!(k[(0, 0)], 6.0);
        assert_eq!(k[(2, 2)], 10.0);
    }
}
