//! Dense row-major `f64` matrix — the core container of the library.
//!
//! All DPP kernels, sub-kernels and intermediate quantities are `Matrix`
//! values. The type is deliberately simple (a `Vec<f64>` plus dims) so that
//! the blocked kernels in [`crate::linalg::matmul`] and the Kronecker
//! routines in [`crate::linalg::kron`] can index raw slices without
//! abstraction overhead.

use crate::error::{Error, Result};
use std::fmt;
use std::ops::{Add, AddAssign, Index, IndexMut, Mul, Neg, Sub, SubAssign};

/// Dense row-major matrix of `f64`.
#[derive(Clone, PartialEq)]
pub struct Matrix {
    rows: usize,
    cols: usize,
    data: Vec<f64>,
}

impl Matrix {
    /// Create a matrix of zeros.
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Matrix { rows, cols, data: vec![0.0; rows * cols] }
    }

    /// Create a matrix filled with `v`.
    pub fn filled(rows: usize, cols: usize, v: f64) -> Self {
        Matrix { rows, cols, data: vec![v; rows * cols] }
    }

    /// Identity matrix of size `n`.
    pub fn identity(n: usize) -> Self {
        let mut m = Matrix::zeros(n, n);
        for i in 0..n {
            m.data[i * n + i] = 1.0;
        }
        m
    }

    /// Diagonal matrix from a slice.
    pub fn diag(d: &[f64]) -> Self {
        let n = d.len();
        let mut m = Matrix::zeros(n, n);
        for i in 0..n {
            m.data[i * n + i] = d[i];
        }
        m
    }

    /// Build from a row-major `Vec` (takes ownership; length must match).
    pub fn from_vec(rows: usize, cols: usize, data: Vec<f64>) -> Result<Self> {
        if data.len() != rows * cols {
            return Err(Error::Shape(format!(
                "from_vec: {}x{} needs {} elements, got {}",
                rows,
                cols,
                rows * cols,
                data.len()
            )));
        }
        Ok(Matrix { rows, cols, data })
    }

    /// Build from a function of (row, col).
    pub fn from_fn(rows: usize, cols: usize, mut f: impl FnMut(usize, usize) -> f64) -> Self {
        let mut data = Vec::with_capacity(rows * cols);
        for i in 0..rows {
            for j in 0..cols {
                data.push(f(i, j));
            }
        }
        Matrix { rows, cols, data }
    }

    /// Build from nested rows (for tests / small literals).
    pub fn from_rows(rows: &[&[f64]]) -> Result<Self> {
        let r = rows.len();
        if r == 0 {
            return Ok(Matrix::zeros(0, 0));
        }
        let c = rows[0].len();
        let mut data = Vec::with_capacity(r * c);
        for row in rows {
            if row.len() != c {
                return Err(Error::Shape("from_rows: ragged rows".into()));
            }
            data.extend_from_slice(row);
        }
        Ok(Matrix { rows: r, cols: c, data })
    }

    /// Number of rows.
    #[inline(always)]
    pub fn rows(&self) -> usize {
        self.rows
    }

    /// Number of columns.
    #[inline(always)]
    pub fn cols(&self) -> usize {
        self.cols
    }

    /// `(rows, cols)` pair.
    #[inline(always)]
    pub fn shape(&self) -> (usize, usize) {
        (self.rows, self.cols)
    }

    /// True if square.
    #[inline(always)]
    pub fn is_square(&self) -> bool {
        self.rows == self.cols
    }

    /// Raw row-major slice.
    #[inline(always)]
    pub fn as_slice(&self) -> &[f64] {
        &self.data
    }

    /// Raw mutable row-major slice.
    #[inline(always)]
    pub fn as_mut_slice(&mut self) -> &mut [f64] {
        &mut self.data
    }

    /// Consume into the backing `Vec`.
    pub fn into_vec(self) -> Vec<f64> {
        self.data
    }

    /// Unchecked get (debug-asserted).
    #[inline(always)]
    pub fn get(&self, i: usize, j: usize) -> f64 {
        debug_assert!(i < self.rows && j < self.cols);
        self.data[i * self.cols + j]
    }

    /// Unchecked set (debug-asserted).
    #[inline(always)]
    pub fn set(&mut self, i: usize, j: usize, v: f64) {
        debug_assert!(i < self.rows && j < self.cols);
        self.data[i * self.cols + j] = v;
    }

    /// Borrow row `i` as a slice.
    #[inline(always)]
    pub fn row(&self, i: usize) -> &[f64] {
        &self.data[i * self.cols..(i + 1) * self.cols]
    }

    /// Borrow row `i` mutably.
    #[inline(always)]
    pub fn row_mut(&mut self, i: usize) -> &mut [f64] {
        &mut self.data[i * self.cols..(i + 1) * self.cols]
    }

    /// Copy column `j` into a new `Vec`.
    pub fn col(&self, j: usize) -> Vec<f64> {
        (0..self.rows).map(|i| self.get(i, j)).collect()
    }

    /// Transpose into a new matrix (cache-blocked for large sizes).
    pub fn transpose(&self) -> Matrix {
        let mut t = Matrix::zeros(0, 0);
        self.transpose_into(&mut t);
        t
    }

    /// Transpose into a caller-held matrix (resized in place;
    /// allocation-free once capacity suffices). Cache-blocked.
    pub fn transpose_into(&self, t: &mut Matrix) {
        t.resize_zeroed(self.cols, self.rows);
        const B: usize = 32;
        for ib in (0..self.rows).step_by(B) {
            for jb in (0..self.cols).step_by(B) {
                let imax = (ib + B).min(self.rows);
                let jmax = (jb + B).min(self.cols);
                for i in ib..imax {
                    for j in jb..jmax {
                        t.data[j * self.rows + i] = self.data[i * self.cols + j];
                    }
                }
            }
        }
    }

    /// Extract the submatrix indexed by `idx` on both axes: `M[idx, idx]`.
    /// This is the `L_Y` operation at the core of DPP likelihoods.
    pub fn principal_submatrix(&self, idx: &[usize]) -> Matrix {
        let k = idx.len();
        let mut s = Matrix::zeros(k, k);
        for (a, &i) in idx.iter().enumerate() {
            let src = &self.data[i * self.cols..];
            let dst = s.row_mut(a);
            for (b, &j) in idx.iter().enumerate() {
                dst[b] = src[j];
            }
        }
        s
    }

    /// Extract rows `idx` (all columns): `M[idx, :]`.
    pub fn select_rows(&self, idx: &[usize]) -> Matrix {
        let mut s = Matrix::zeros(idx.len(), self.cols);
        for (a, &i) in idx.iter().enumerate() {
            s.row_mut(a).copy_from_slice(self.row(i));
        }
        s
    }

    /// Extract columns `idx` (all rows): `M[:, idx]`.
    pub fn select_cols(&self, idx: &[usize]) -> Matrix {
        let mut s = Matrix::zeros(self.rows, idx.len());
        for i in 0..self.rows {
            let src = self.row(i);
            let dst = s.row_mut(i);
            for (b, &j) in idx.iter().enumerate() {
                dst[b] = src[j];
            }
        }
        s
    }

    /// Trace (sum of diagonal).
    pub fn trace(&self) -> f64 {
        let n = self.rows.min(self.cols);
        (0..n).map(|i| self.data[i * self.cols + i]).sum()
    }

    /// Diagonal entries as a `Vec`.
    pub fn diagonal(&self) -> Vec<f64> {
        let n = self.rows.min(self.cols);
        (0..n).map(|i| self.data[i * self.cols + i]).collect()
    }

    /// Frobenius norm.
    pub fn fro_norm(&self) -> f64 {
        self.data.iter().map(|x| x * x).sum::<f64>().sqrt()
    }

    /// Max absolute entry.
    pub fn max_abs(&self) -> f64 {
        self.data.iter().fold(0.0f64, |m, &x| m.max(x.abs()))
    }

    /// Frobenius inner product `<A, B> = Tr(AᵀB)`.
    pub fn fro_dot(&self, other: &Matrix) -> Result<f64> {
        if self.shape() != other.shape() {
            return Err(Error::Shape("fro_dot: shape mismatch".into()));
        }
        Ok(self.data.iter().zip(&other.data).map(|(a, b)| a * b).sum())
    }

    /// Elementwise map into a new matrix.
    pub fn map(&self, f: impl Fn(f64) -> f64) -> Matrix {
        Matrix {
            rows: self.rows,
            cols: self.cols,
            data: self.data.iter().map(|&x| f(x)).collect(),
        }
    }

    /// In-place scale by a scalar.
    pub fn scale_mut(&mut self, s: f64) {
        for x in &mut self.data {
            *x *= s;
        }
    }

    /// Scaled copy.
    pub fn scaled(&self, s: f64) -> Matrix {
        self.map(|x| x * s)
    }

    /// In-place `self += alpha * other`.
    pub fn axpy(&mut self, alpha: f64, other: &Matrix) -> Result<()> {
        if self.shape() != other.shape() {
            return Err(Error::Shape("axpy: shape mismatch".into()));
        }
        for (a, b) in self.data.iter_mut().zip(&other.data) {
            *a += alpha * b;
        }
        Ok(())
    }

    /// Add `alpha` to the diagonal in place (e.g. `L + I`).
    pub fn add_diag_mut(&mut self, alpha: f64) {
        let n = self.rows.min(self.cols);
        for i in 0..n {
            self.data[i * self.cols + i] += alpha;
        }
    }

    /// Symmetrize in place: `A ← (A + Aᵀ)/2`. Keeps iterates numerically
    /// symmetric across repeated updates.
    pub fn symmetrize_mut(&mut self) {
        debug_assert!(self.is_square());
        let n = self.rows;
        for i in 0..n {
            for j in (i + 1)..n {
                let a = self.data[i * n + j];
                let b = self.data[j * n + i];
                let m = 0.5 * (a + b);
                self.data[i * n + j] = m;
                self.data[j * n + i] = m;
            }
        }
    }

    /// Matrix-vector product `y = A x`.
    pub fn matvec(&self, x: &[f64]) -> Result<Vec<f64>> {
        if x.len() != self.cols {
            return Err(Error::Shape(format!(
                "matvec: {}x{} times vec of len {}",
                self.rows,
                self.cols,
                x.len()
            )));
        }
        let mut y = vec![0.0; self.rows];
        for i in 0..self.rows {
            let row = self.row(i);
            let mut acc = 0.0;
            for (a, b) in row.iter().zip(x) {
                acc += a * b;
            }
            y[i] = acc;
        }
        Ok(y)
    }

    /// Vector-matrix product `y = xᵀ A` (returns a row as `Vec`).
    pub fn vecmat(&self, x: &[f64]) -> Result<Vec<f64>> {
        if x.len() != self.rows {
            return Err(Error::Shape("vecmat: length mismatch".into()));
        }
        let mut y = vec![0.0; self.cols];
        for i in 0..self.rows {
            let xi = x[i];
            if xi == 0.0 {
                continue;
            }
            let row = self.row(i);
            for (yj, a) in y.iter_mut().zip(row) {
                *yj += xi * a;
            }
        }
        Ok(y)
    }

    /// Quadratic form `xᵀ A x`.
    pub fn quad_form(&self, x: &[f64]) -> Result<f64> {
        let ax = self.matvec(x)?;
        Ok(x.iter().zip(&ax).map(|(a, b)| a * b).sum())
    }

    /// Check symmetry up to `tol` (max abs difference).
    pub fn is_symmetric(&self, tol: f64) -> bool {
        if !self.is_square() {
            return false;
        }
        let n = self.rows;
        for i in 0..n {
            for j in (i + 1)..n {
                if (self.data[i * n + j] - self.data[j * n + i]).abs() > tol {
                    return false;
                }
            }
        }
        true
    }

    /// Copy `block` into `self` starting at `(i0, j0)`.
    pub fn set_block(&mut self, i0: usize, j0: usize, block: &Matrix) -> Result<()> {
        if i0 + block.rows > self.rows || j0 + block.cols > self.cols {
            return Err(Error::Shape("set_block: out of bounds".into()));
        }
        for i in 0..block.rows {
            let dst =
                &mut self.data[(i0 + i) * self.cols + j0..(i0 + i) * self.cols + j0 + block.cols];
            dst.copy_from_slice(block.row(i));
        }
        Ok(())
    }

    /// Extract the `r x c` block at `(i0, j0)`.
    pub fn block(&self, i0: usize, j0: usize, r: usize, c: usize) -> Result<Matrix> {
        if i0 + r > self.rows || j0 + c > self.cols {
            return Err(Error::Shape("block: out of bounds".into()));
        }
        let mut b = Matrix::zeros(r, c);
        for i in 0..r {
            b.row_mut(i)
                .copy_from_slice(&self.data[(i0 + i) * self.cols + j0..(i0 + i) * self.cols + j0 + c]);
        }
        Ok(b)
    }

    /// Reshape in place to `rows × cols`, zero-filled, reusing the
    /// allocation (no heap traffic once capacity suffices). The workhorse
    /// of the `_into` APIs that keep steady-state iterations allocation-free.
    pub fn resize_zeroed(&mut self, rows: usize, cols: usize) {
        self.data.clear();
        self.data.resize(rows * cols, 0.0);
        self.rows = rows;
        self.cols = cols;
    }

    /// Copy `other` into `self`, resizing as needed (allocation-free once
    /// capacity suffices).
    pub fn copy_from(&mut self, other: &Matrix) {
        self.data.clear();
        self.data.extend_from_slice(&other.data);
        self.rows = other.rows;
        self.cols = other.cols;
    }

    /// Relative Frobenius distance `‖A−B‖_F / max(1, ‖B‖_F)`.
    pub fn rel_diff(&self, other: &Matrix) -> f64 {
        assert_eq!(self.shape(), other.shape());
        let mut num = 0.0;
        let mut den = 0.0;
        for (a, b) in self.data.iter().zip(&other.data) {
            num += (a - b) * (a - b);
            den += b * b;
        }
        num.sqrt() / den.sqrt().max(1.0)
    }
}

impl Default for Matrix {
    /// The empty `0×0` matrix — the starting state of every `_into` /
    /// scratch buffer (`resize_zeroed` grows it on first use), which is
    /// what lets the scratch structs (`SymEigenScratch`,
    /// `MarginalScratch`, `ConditionScratch`, …) `#[derive(Default)]`.
    fn default() -> Self {
        Matrix::zeros(0, 0)
    }
}

impl Index<(usize, usize)> for Matrix {
    type Output = f64;
    #[inline(always)]
    fn index(&self, (i, j): (usize, usize)) -> &f64 {
        &self.data[i * self.cols + j]
    }
}

impl IndexMut<(usize, usize)> for Matrix {
    #[inline(always)]
    fn index_mut(&mut self, (i, j): (usize, usize)) -> &mut f64 {
        &mut self.data[i * self.cols + j]
    }
}

impl Add<&Matrix> for &Matrix {
    type Output = Matrix;
    fn add(self, rhs: &Matrix) -> Matrix {
        assert_eq!(self.shape(), rhs.shape(), "add: shape mismatch");
        let data = self.data.iter().zip(&rhs.data).map(|(a, b)| a + b).collect();
        Matrix { rows: self.rows, cols: self.cols, data }
    }
}

impl Sub<&Matrix> for &Matrix {
    type Output = Matrix;
    fn sub(self, rhs: &Matrix) -> Matrix {
        assert_eq!(self.shape(), rhs.shape(), "sub: shape mismatch");
        let data = self.data.iter().zip(&rhs.data).map(|(a, b)| a - b).collect();
        Matrix { rows: self.rows, cols: self.cols, data }
    }
}

impl AddAssign<&Matrix> for Matrix {
    fn add_assign(&mut self, rhs: &Matrix) {
        assert_eq!(self.shape(), rhs.shape(), "add_assign: shape mismatch");
        for (a, b) in self.data.iter_mut().zip(&rhs.data) {
            *a += b;
        }
    }
}

impl SubAssign<&Matrix> for Matrix {
    fn sub_assign(&mut self, rhs: &Matrix) {
        assert_eq!(self.shape(), rhs.shape(), "sub_assign: shape mismatch");
        for (a, b) in self.data.iter_mut().zip(&rhs.data) {
            *a -= b;
        }
    }
}

impl Neg for &Matrix {
    type Output = Matrix;
    fn neg(self) -> Matrix {
        self.map(|x| -x)
    }
}

impl Mul<f64> for &Matrix {
    type Output = Matrix;
    fn mul(self, s: f64) -> Matrix {
        self.scaled(s)
    }
}

impl fmt::Debug for Matrix {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        writeln!(f, "Matrix {}x{} [", self.rows, self.cols)?;
        let show_r = self.rows.min(8);
        let show_c = self.cols.min(8);
        for i in 0..show_r {
            write!(f, "  ")?;
            for j in 0..show_c {
                write!(f, "{:>12.5} ", self.get(i, j))?;
            }
            if self.cols > show_c {
                write!(f, "...")?;
            }
            writeln!(f)?;
        }
        if self.rows > show_r {
            writeln!(f, "  ...")?;
        }
        write!(f, "]")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn construction_and_indexing() {
        let m = Matrix::from_fn(3, 4, |i, j| (i * 10 + j) as f64);
        assert_eq!(m.shape(), (3, 4));
        assert_eq!(m[(2, 3)], 23.0);
        assert_eq!(m.row(1), &[10.0, 11.0, 12.0, 13.0]);
        assert_eq!(m.col(2), vec![2.0, 12.0, 22.0]);
    }

    #[test]
    fn identity_and_diag() {
        let i3 = Matrix::identity(3);
        assert_eq!(i3.trace(), 3.0);
        let d = Matrix::diag(&[1.0, 2.0, 3.0]);
        assert_eq!(d[(1, 1)], 2.0);
        assert_eq!(d[(0, 1)], 0.0);
    }

    #[test]
    fn from_vec_shape_check() {
        assert!(Matrix::from_vec(2, 2, vec![1.0; 3]).is_err());
        assert!(Matrix::from_vec(2, 2, vec![1.0; 4]).is_ok());
    }

    #[test]
    fn transpose_roundtrip() {
        let m = Matrix::from_fn(37, 53, |i, j| (i * 53 + j) as f64);
        let t = m.transpose();
        assert_eq!(t.shape(), (53, 37));
        assert_eq!(t[(10, 20)], m[(20, 10)]);
        assert_eq!(t.transpose(), m);
    }

    #[test]
    fn principal_submatrix_matches_manual() {
        let m = Matrix::from_fn(5, 5, |i, j| (i * 5 + j) as f64);
        let s = m.principal_submatrix(&[1, 3]);
        assert_eq!(s[(0, 0)], m[(1, 1)]);
        assert_eq!(s[(0, 1)], m[(1, 3)]);
        assert_eq!(s[(1, 0)], m[(3, 1)]);
        assert_eq!(s[(1, 1)], m[(3, 3)]);
    }

    #[test]
    fn select_rows_cols() {
        let m = Matrix::from_fn(4, 4, |i, j| (i * 4 + j) as f64);
        let r = m.select_rows(&[0, 2]);
        assert_eq!(r.shape(), (2, 4));
        assert_eq!(r.row(1), m.row(2));
        let c = m.select_cols(&[1, 3]);
        assert_eq!(c.shape(), (4, 2));
        assert_eq!(c[(2, 0)], m[(2, 1)]);
    }

    #[test]
    fn matvec_vecmat_quadform() {
        let m = Matrix::from_rows(&[&[1.0, 2.0], &[3.0, 4.0]]).unwrap();
        assert_eq!(m.matvec(&[1.0, 1.0]).unwrap(), vec![3.0, 7.0]);
        assert_eq!(m.vecmat(&[1.0, 1.0]).unwrap(), vec![4.0, 6.0]);
        // x^T A x = [1,1] [3,7]^T = 10
        assert_eq!(m.quad_form(&[1.0, 1.0]).unwrap(), 10.0);
    }

    #[test]
    fn norms_and_trace() {
        let m = Matrix::from_rows(&[&[3.0, 0.0], &[0.0, 4.0]]).unwrap();
        assert!((m.fro_norm() - 5.0).abs() < 1e-12);
        assert_eq!(m.trace(), 7.0);
        assert_eq!(m.max_abs(), 4.0);
    }

    #[test]
    fn arithmetic_ops() {
        let a = Matrix::from_rows(&[&[1.0, 2.0], &[3.0, 4.0]]).unwrap();
        let b = Matrix::identity(2);
        let c = &a + &b;
        assert_eq!(c[(0, 0)], 2.0);
        let d = &c - &b;
        assert_eq!(d, a);
        let mut e = a.clone();
        e.axpy(2.0, &b).unwrap();
        assert_eq!(e[(1, 1)], 6.0);
        let f = &a * 2.0;
        assert_eq!(f[(0, 1)], 4.0);
    }

    #[test]
    fn symmetrize_and_check() {
        let mut m = Matrix::from_rows(&[&[1.0, 2.0], &[4.0, 1.0]]).unwrap();
        assert!(!m.is_symmetric(1e-12));
        m.symmetrize_mut();
        assert!(m.is_symmetric(1e-12));
        assert_eq!(m[(0, 1)], 3.0);
    }

    #[test]
    fn blocks() {
        let mut m = Matrix::zeros(4, 4);
        let b = Matrix::filled(2, 2, 7.0);
        m.set_block(1, 2, &b).unwrap();
        assert_eq!(m[(1, 2)], 7.0);
        assert_eq!(m[(2, 3)], 7.0);
        assert_eq!(m[(0, 0)], 0.0);
        let g = m.block(1, 2, 2, 2).unwrap();
        assert_eq!(g, b);
        assert!(m.block(3, 3, 2, 2).is_err());
    }

    #[test]
    fn default_is_empty() {
        let m = Matrix::default();
        assert_eq!(m.shape(), (0, 0));
        assert!(m.as_slice().is_empty());
    }

    #[test]
    fn resize_and_copy_reuse_storage() {
        let mut m = Matrix::filled(4, 4, 3.0);
        m.resize_zeroed(2, 3);
        assert_eq!(m.shape(), (2, 3));
        assert_eq!(m.as_slice(), &[0.0; 6]);
        let src = Matrix::from_fn(3, 2, |i, j| (i + j) as f64);
        m.copy_from(&src);
        assert_eq!(m, src);
    }

    #[test]
    fn add_diag() {
        let mut m = Matrix::zeros(3, 3);
        m.add_diag_mut(2.5);
        assert_eq!(m.trace(), 7.5);
        assert_eq!(m[(0, 1)], 0.0);
    }
}
