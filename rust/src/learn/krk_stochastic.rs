//! Stochastic KRK-Picard (Thm. 3.3, second half).
//!
//! Instead of the full `Θ = (1/n)Σ_i U_i L_{Y_i}⁻¹U_iᵀ`, each half-update
//! uses a minibatch estimate `Θ_B = (1/|B|)Σ_{i∈B} U_i L_{Y_i}⁻¹U_iᵀ`,
//! which has only `O(|B|κ²)` non-zeros — so the Θ-contractions are
//! accumulated straight from the minibatch subset inverses by
//! [`ThetaEngine::contract_batch`] (`O(|B|κ²)` per update, no sparse
//! matrix, no kernel or subset clones), and the `(I+L)⁻¹` half is
//! unchanged (sub-eigenbases, `O(N₁³+N₂³)`), giving the paper's
//! `O(Nκ² + N^{3/2})` time and `O(N + κ²)` space per iteration — this is
//! the configuration that learns kernels too large to fit in memory
//! (Fig. 1c).
//!
//! **Streaming deltas.** [`Learner::step_delta`] is overridden here: each
//! stochastic step's per-factor change `L₁' − L₁` is compressed to its
//! top-[`DELTA_RANK_CAP`] eigendirections and emitted as
//! [`KernelDelta::Perturb`]s, and the *compressed* step is written back
//! into the learner's own iterate (classic gradient compression) — so a
//! serving tenant absorbing the deltas through
//! [`crate::coordinator::KernelRegistry::publish_delta`] holds exactly
//! the learner's kernel, bitwise, while its cached eigendecomposition is
//! refreshed by `O(r·N₁²)` secular updates instead of `O(N₁³)` rebuilds.

use crate::dpp::{Kernel, KernelDelta};
use crate::error::Result;
use crate::learn::krk::{b2_matrix_into, l1_b_l1_into, KrkScratch};
use crate::learn::stats::{Contraction, KernelRef, ThetaEngine};
use crate::learn::traits::{Learner, TrainingSet};
use crate::linalg::{matmul, Matrix, SymEigen};
use crate::rng::Rng;

/// Rank cap for the per-factor delta compression of one stochastic step.
/// A minibatch half-update concentrates its spectral mass in a handful of
/// directions; whatever the cap truncates is *also dropped from the
/// learner's iterate* (write-back), so learner and tenant never disagree
/// — truncation becomes optimization noise, not serving drift.
pub const DELTA_RANK_CAP: usize = 8;

/// Eigendirections carrying less than this fraction of a step's total
/// spectral mass are dropped (numerical dust from symmetrization).
const DELTA_ENERGY_TOL: f64 = 1e-12;

/// Top-[`DELTA_RANK_CAP`] spectral compression of `cur − prev`. Returns
/// `None` when the step was a numerical no-op for this factor.
fn compress_step(prev: &Matrix, cur: &Matrix) -> Result<Option<(Vec<f64>, Matrix)>> {
    let n = prev.rows();
    let mut diff = cur.clone();
    diff.axpy(-1.0, prev)?;
    let eig = SymEigen::new(&diff)?;
    let total: f64 = eig.values.iter().map(|v| v.abs()).sum();
    if !(total > 0.0) {
        return Ok(None);
    }
    let mut order: Vec<usize> = (0..n).collect();
    order.sort_by(|&a, &b| eig.values[b].abs().total_cmp(&eig.values[a].abs()));
    let kept: Vec<usize> = order
        .into_iter()
        .take(DELTA_RANK_CAP)
        .take_while(|&i| eig.values[i].abs() > DELTA_ENERGY_TOL * total)
        .collect();
    if kept.is_empty() {
        return Ok(None);
    }
    let rhos: Vec<f64> = kept.iter().map(|&i| eig.values[i]).collect();
    let vectors = Matrix::from_fn(n, kept.len(), |r, c| eig.vectors.get(r, kept[c]));
    Ok(Some((rhos, vectors)))
}

/// Stochastic/minibatch KRK-Picard learner.
pub struct KrkStochastic {
    l1: Matrix,
    l2: Matrix,
    /// Step size `a`.
    pub step_size: f64,
    /// Minibatch size (1 = pure stochastic, as in the paper's Fig. 2b).
    pub minibatch: usize,
    rng: Rng,
    cursor: usize,
    order: Vec<usize>,
    /// Shared KRK workspaces (eigen scratches, GEMM pack buffers, sandwich
    /// outputs) — the dense half of each stochastic step reuses them.
    scratch: KrkScratch,
    /// Minibatch Θ-contraction engine (per-subset gather/factor buffers).
    engine: ThetaEngine,
}

impl KrkStochastic {
    /// Start from PD sub-kernels.
    pub fn new(l1: Matrix, l2: Matrix, step_size: f64, minibatch: usize, seed: u64) -> Self {
        KrkStochastic {
            l1,
            l2,
            step_size,
            minibatch: minibatch.max(1),
            rng: Rng::new(seed),
            cursor: 0,
            order: Vec::new(),
            scratch: KrkScratch::default(),
            engine: ThetaEngine::new(),
        }
    }

    /// Borrow the current sub-kernels.
    pub fn subkernels(&self) -> (&Matrix, &Matrix) {
        (&self.l1, &self.l2)
    }

    /// Next minibatch of subset indices (reshuffled each epoch).
    fn next_batch(&mut self, n: usize) -> Vec<usize> {
        let mut out = Vec::with_capacity(self.minibatch);
        for _ in 0..self.minibatch {
            if self.cursor >= self.order.len() {
                self.order = (0..n).collect();
                let mut order = std::mem::take(&mut self.order);
                self.rng.shuffle(&mut order);
                self.order = order;
                self.cursor = 0;
            }
            out.push(self.order[self.cursor]);
            self.cursor += 1;
        }
        out
    }

    /// One stochastic L₁ half-update: `A₁` accumulated straight from the
    /// minibatch subset inverses; the dense algebra runs in the shared
    /// [`KrkScratch`] buffers.
    fn update_l1(&mut self, data: &TrainingSet, batch: &[usize]) -> Result<()> {
        let n2 = self.l2.rows();
        let s = &mut self.scratch;
        self.engine.contract_batch(
            KernelRef::Kron2(&self.l1, &self.l2),
            &data.subsets,
            batch,
            1.0 / batch.len() as f64,
            Contraction::A1,
            &mut s.contr,
        )?;
        matmul::sandwich_into(&mut s.sand, &self.l1, &s.contr, &self.l1, &mut s.tmp, &mut s.gemm)?;
        l1_b_l1_into(&self.l1, &self.l2, s)?;
        s.sand -= &s.bmat;
        self.l1.axpy(self.step_size / n2 as f64, &s.sand)?;
        self.l1.symmetrize_mut();
        Ok(())
    }

    /// One stochastic L₂ half-update.
    fn update_l2(&mut self, data: &TrainingSet, batch: &[usize]) -> Result<()> {
        let n1 = self.l1.rows();
        let s = &mut self.scratch;
        self.engine.contract_batch(
            KernelRef::Kron2(&self.l1, &self.l2),
            &data.subsets,
            batch,
            1.0 / batch.len() as f64,
            Contraction::A2,
            &mut s.contr,
        )?;
        matmul::sandwich_into(&mut s.sand, &self.l2, &s.contr, &self.l2, &mut s.tmp, &mut s.gemm)?;
        b2_matrix_into(&self.l1, &self.l2, s)?;
        s.sand -= &s.bmat;
        self.l2.axpy(self.step_size / n1 as f64, &s.sand)?;
        self.l2.symmetrize_mut();
        Ok(())
    }
}

impl Learner for KrkStochastic {
    fn name(&self) -> &'static str {
        "krk-stochastic"
    }

    fn step(&mut self, data: &TrainingSet) -> Result<()> {
        let batch = self.next_batch(data.len());
        self.update_l1(data, &batch)?;
        let batch = self.next_batch(data.len());
        self.update_l2(data, &batch)?;
        Ok(())
    }

    /// One stochastic step, emitted as rank-capped per-factor
    /// [`KernelDelta::Perturb`]s (see the module docs). The compressed
    /// step is replayed back into the iterate through the same
    /// [`KernelDelta::apply`] the registry's ground-truth path uses, so
    /// applying the returned deltas to the pre-step kernel reproduces
    /// `self.kernel()` bitwise.
    fn step_delta(&mut self, data: &TrainingSet) -> Result<Option<Vec<KernelDelta>>> {
        let prev1 = self.l1.clone();
        let prev2 = self.l2.clone();
        self.step(data)?;
        let mut deltas = Vec::new();
        if let Some((rhos, vectors)) = compress_step(&prev1, &self.l1)? {
            deltas.push(KernelDelta::Perturb { side: 0, rhos, vectors });
        }
        if let Some((rhos, vectors)) = compress_step(&prev2, &self.l2)? {
            deltas.push(KernelDelta::Perturb { side: 1, rhos, vectors });
        }
        let mut kernel = Kernel::Kron2(prev1, prev2);
        for d in &deltas {
            kernel = d.apply(&kernel)?;
        }
        if let Kernel::Kron2(l1, l2) = kernel {
            self.l1 = l1;
            self.l2 = l2;
        }
        Ok(Some(deltas))
    }

    fn kernel(&self) -> Kernel {
        Kernel::Kron2(self.l1.clone(), self.l2.clone())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dpp::likelihood::log_likelihood;
    use crate::dpp::Sampler;
    use crate::linalg::cholesky;

    fn sub_kernel(n: usize, rng: &mut Rng) -> Matrix {
        let mut l = rng.paper_init_kernel(n);
        l.scale_mut(1.5 / n as f64);
        l.add_diag_mut(0.3);
        l
    }

    fn setup(n1: usize, n2: usize, count: usize, seed: u64) -> (TrainingSet, KrkStochastic) {
        let mut rng = Rng::new(seed);
        let truth = Kernel::Kron2(sub_kernel(n1, &mut rng), sub_kernel(n2, &mut rng));
        let sampler = Sampler::new(&truth).unwrap();
        let subsets: Vec<Vec<usize>> =
            (0..count).map(|_| sampler.sample(&mut rng)).collect();
        let data = TrainingSet::new(n1 * n2, subsets).unwrap();
        let learner = KrkStochastic::new(
            sub_kernel(n1, &mut rng),
            sub_kernel(n2, &mut rng),
            0.6, // conservative stochastic step
            4,
            seed ^ 0xABCD,
        );
        (data, learner)
    }

    #[test]
    fn improves_likelihood_on_average() {
        let (data, mut learner) = setup(3, 4, 50, 21);
        let ll0 = log_likelihood(&learner.kernel(), &data.subsets).unwrap();
        for _ in 0..30 {
            learner.step(&data).unwrap();
        }
        let ll1 = log_likelihood(&learner.kernel(), &data.subsets).unwrap();
        assert!(ll1 > ll0, "stochastic learning failed to improve: {ll0} -> {ll1}");
    }

    #[test]
    fn iterates_stay_pd() {
        let (data, mut learner) = setup(3, 3, 40, 23);
        for _ in 0..25 {
            learner.step(&data).unwrap();
            let (l1, l2) = learner.subkernels();
            assert!(cholesky::is_pd(l1));
            assert!(cholesky::is_pd(l2));
        }
    }

    #[test]
    fn epoch_reshuffling_covers_all_subsets() {
        let (data, mut learner) = setup(2, 3, 10, 25);
        let mut seen = vec![false; 10];
        // 5 steps × (2 batches × 4) = 40 draws > 3 epochs of 10.
        for _ in 0..5 {
            for idx in learner.next_batch(data.len()) {
                seen[idx] = true;
            }
            for idx in learner.next_batch(data.len()) {
                seen[idx] = true;
            }
        }
        assert!(seen.iter().all(|&s| s), "epoch shuffling skipped subsets: {seen:?}");
    }

    #[test]
    fn batch_contraction_matches_sparse_theta_reference() {
        // The engine's direct minibatch accumulation must agree with the
        // sparse-Θ path it replaced (kept in dpp::likelihood as oracle).
        let (data, learner) = setup(3, 4, 20, 29);
        let (l1, l2) = learner.subkernels();
        let kernel = Kernel::Kron2(l1.clone(), l2.clone());
        let batch = [0usize, 3, 7, 7]; // repeat included
        let subsets: Vec<Vec<usize>> =
            batch.iter().map(|&i| data.subsets[i].clone()).collect();
        let theta =
            crate::dpp::likelihood::theta_sparse(&kernel, &subsets, 0.25).unwrap();
        let a1_ref = theta.block_trace(l2, 3, 4).unwrap();
        let a2_ref = theta.weighted_block_sum(l1, 3, 4).unwrap();
        let mut eng = ThetaEngine::new();
        let mut out = Matrix::zeros(0, 0);
        eng.contract_batch(
            KernelRef::Kron2(l1, l2),
            &data.subsets,
            &batch,
            0.25,
            Contraction::A1,
            &mut out,
        )
        .unwrap();
        assert!(out.rel_diff(&a1_ref) < 1e-12, "A1: {}", out.rel_diff(&a1_ref));
        eng.contract_batch(
            KernelRef::Kron2(l1, l2),
            &data.subsets,
            &batch,
            0.25,
            Contraction::A2,
            &mut out,
        )
        .unwrap();
        assert!(out.rel_diff(&a2_ref) < 1e-12, "A2: {}", out.rel_diff(&a2_ref));
    }

    #[test]
    fn step_delta_reproduces_iterate_exactly_and_bounds_rank() {
        let (data, mut learner) = setup(3, 4, 30, 31);
        for _ in 0..5 {
            let before = learner.kernel();
            let deltas = learner
                .step_delta(&data)
                .unwrap()
                .expect("krk-stochastic always emits a delta form");
            assert!(!deltas.is_empty(), "a stochastic step should move the kernel");
            let mut replay = before;
            for d in &deltas {
                assert!(!d.is_structural());
                assert!(d.rank() <= DELTA_RANK_CAP, "rank {} > cap", d.rank());
                replay = d.apply(&replay).unwrap();
            }
            // The write-back contract: deltas replayed on the pre-step
            // kernel reproduce the learner's iterate bitwise.
            match (&replay, &learner.kernel()) {
                (Kernel::Kron2(a1, b1), Kernel::Kron2(a2, b2)) => {
                    assert_eq!(a1.as_slice(), a2.as_slice());
                    assert_eq!(b1.as_slice(), b2.as_slice());
                }
                _ => panic!("kernel structure changed"),
            }
        }
    }

    #[test]
    fn compressed_steps_still_improve_likelihood_and_stay_pd() {
        let (data, mut learner) = setup(3, 4, 50, 33);
        let ll0 = log_likelihood(&learner.kernel(), &data.subsets).unwrap();
        for _ in 0..30 {
            learner.step_delta(&data).unwrap();
            let (l1, l2) = learner.subkernels();
            assert!(cholesky::is_pd(l1) && cholesky::is_pd(l2));
        }
        let ll1 = log_likelihood(&learner.kernel(), &data.subsets).unwrap();
        assert!(ll1 > ll0, "compressed stochastic learning failed to improve: {ll0} -> {ll1}");
    }

    #[test]
    fn minibatch_one_runs() {
        let (data, mut learner) = setup(2, 2, 20, 27);
        learner.minibatch = 1;
        for _ in 0..10 {
            learner.step(&data).unwrap();
        }
        let (l1, l2) = learner.subkernels();
        assert!(cholesky::is_pd(l1) && cholesky::is_pd(l2));
    }
}
