//! Stochastic KRK-Picard (Thm. 3.3, second half).
//!
//! Instead of the full `Θ = (1/n)Σ_i U_i L_{Y_i}⁻¹U_iᵀ`, each half-update
//! uses a minibatch estimate `Θ_B = (1/|B|)Σ_{i∈B} U_i L_{Y_i}⁻¹U_iᵀ`,
//! which has only `O(|B|κ²)` non-zeros — so the Θ-contractions are
//! accumulated straight from the minibatch subset inverses by
//! [`ThetaEngine::contract_batch`] (`O(|B|κ²)` per update, no sparse
//! matrix, no kernel or subset clones), and the `(I+L)⁻¹` half is
//! unchanged (sub-eigenbases, `O(N₁³+N₂³)`), giving the paper's
//! `O(Nκ² + N^{3/2})` time and `O(N + κ²)` space per iteration — this is
//! the configuration that learns kernels too large to fit in memory
//! (Fig. 1c).

use crate::dpp::Kernel;
use crate::error::Result;
use crate::learn::krk::{b2_matrix_into, l1_b_l1_into, KrkScratch};
use crate::learn::stats::{Contraction, KernelRef, ThetaEngine};
use crate::learn::traits::{Learner, TrainingSet};
use crate::linalg::{matmul, Matrix};
use crate::rng::Rng;

/// Stochastic/minibatch KRK-Picard learner.
pub struct KrkStochastic {
    l1: Matrix,
    l2: Matrix,
    /// Step size `a`.
    pub step_size: f64,
    /// Minibatch size (1 = pure stochastic, as in the paper's Fig. 2b).
    pub minibatch: usize,
    rng: Rng,
    cursor: usize,
    order: Vec<usize>,
    /// Shared KRK workspaces (eigen scratches, GEMM pack buffers, sandwich
    /// outputs) — the dense half of each stochastic step reuses them.
    scratch: KrkScratch,
    /// Minibatch Θ-contraction engine (per-subset gather/factor buffers).
    engine: ThetaEngine,
}

impl KrkStochastic {
    /// Start from PD sub-kernels.
    pub fn new(l1: Matrix, l2: Matrix, step_size: f64, minibatch: usize, seed: u64) -> Self {
        KrkStochastic {
            l1,
            l2,
            step_size,
            minibatch: minibatch.max(1),
            rng: Rng::new(seed),
            cursor: 0,
            order: Vec::new(),
            scratch: KrkScratch::default(),
            engine: ThetaEngine::new(),
        }
    }

    /// Borrow the current sub-kernels.
    pub fn subkernels(&self) -> (&Matrix, &Matrix) {
        (&self.l1, &self.l2)
    }

    /// Next minibatch of subset indices (reshuffled each epoch).
    fn next_batch(&mut self, n: usize) -> Vec<usize> {
        let mut out = Vec::with_capacity(self.minibatch);
        for _ in 0..self.minibatch {
            if self.cursor >= self.order.len() {
                self.order = (0..n).collect();
                let mut order = std::mem::take(&mut self.order);
                self.rng.shuffle(&mut order);
                self.order = order;
                self.cursor = 0;
            }
            out.push(self.order[self.cursor]);
            self.cursor += 1;
        }
        out
    }

    /// One stochastic L₁ half-update: `A₁` accumulated straight from the
    /// minibatch subset inverses; the dense algebra runs in the shared
    /// [`KrkScratch`] buffers.
    fn update_l1(&mut self, data: &TrainingSet, batch: &[usize]) -> Result<()> {
        let n2 = self.l2.rows();
        let s = &mut self.scratch;
        self.engine.contract_batch(
            KernelRef::Kron2(&self.l1, &self.l2),
            &data.subsets,
            batch,
            1.0 / batch.len() as f64,
            Contraction::A1,
            &mut s.contr,
        )?;
        matmul::sandwich_into(&mut s.sand, &self.l1, &s.contr, &self.l1, &mut s.tmp, &mut s.gemm)?;
        l1_b_l1_into(&self.l1, &self.l2, s)?;
        s.sand -= &s.bmat;
        self.l1.axpy(self.step_size / n2 as f64, &s.sand)?;
        self.l1.symmetrize_mut();
        Ok(())
    }

    /// One stochastic L₂ half-update.
    fn update_l2(&mut self, data: &TrainingSet, batch: &[usize]) -> Result<()> {
        let n1 = self.l1.rows();
        let s = &mut self.scratch;
        self.engine.contract_batch(
            KernelRef::Kron2(&self.l1, &self.l2),
            &data.subsets,
            batch,
            1.0 / batch.len() as f64,
            Contraction::A2,
            &mut s.contr,
        )?;
        matmul::sandwich_into(&mut s.sand, &self.l2, &s.contr, &self.l2, &mut s.tmp, &mut s.gemm)?;
        b2_matrix_into(&self.l1, &self.l2, s)?;
        s.sand -= &s.bmat;
        self.l2.axpy(self.step_size / n1 as f64, &s.sand)?;
        self.l2.symmetrize_mut();
        Ok(())
    }
}

impl Learner for KrkStochastic {
    fn name(&self) -> &'static str {
        "krk-stochastic"
    }

    fn step(&mut self, data: &TrainingSet) -> Result<()> {
        let batch = self.next_batch(data.len());
        self.update_l1(data, &batch)?;
        let batch = self.next_batch(data.len());
        self.update_l2(data, &batch)?;
        Ok(())
    }

    fn kernel(&self) -> Kernel {
        Kernel::Kron2(self.l1.clone(), self.l2.clone())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dpp::likelihood::log_likelihood;
    use crate::dpp::Sampler;
    use crate::linalg::cholesky;

    fn sub_kernel(n: usize, rng: &mut Rng) -> Matrix {
        let mut l = rng.paper_init_kernel(n);
        l.scale_mut(1.5 / n as f64);
        l.add_diag_mut(0.3);
        l
    }

    fn setup(n1: usize, n2: usize, count: usize, seed: u64) -> (TrainingSet, KrkStochastic) {
        let mut rng = Rng::new(seed);
        let truth = Kernel::Kron2(sub_kernel(n1, &mut rng), sub_kernel(n2, &mut rng));
        let sampler = Sampler::new(&truth).unwrap();
        let subsets: Vec<Vec<usize>> =
            (0..count).map(|_| sampler.sample(&mut rng)).collect();
        let data = TrainingSet::new(n1 * n2, subsets).unwrap();
        let learner = KrkStochastic::new(
            sub_kernel(n1, &mut rng),
            sub_kernel(n2, &mut rng),
            0.6, // conservative stochastic step
            4,
            seed ^ 0xABCD,
        );
        (data, learner)
    }

    #[test]
    fn improves_likelihood_on_average() {
        let (data, mut learner) = setup(3, 4, 50, 21);
        let ll0 = log_likelihood(&learner.kernel(), &data.subsets).unwrap();
        for _ in 0..30 {
            learner.step(&data).unwrap();
        }
        let ll1 = log_likelihood(&learner.kernel(), &data.subsets).unwrap();
        assert!(ll1 > ll0, "stochastic learning failed to improve: {ll0} -> {ll1}");
    }

    #[test]
    fn iterates_stay_pd() {
        let (data, mut learner) = setup(3, 3, 40, 23);
        for _ in 0..25 {
            learner.step(&data).unwrap();
            let (l1, l2) = learner.subkernels();
            assert!(cholesky::is_pd(l1));
            assert!(cholesky::is_pd(l2));
        }
    }

    #[test]
    fn epoch_reshuffling_covers_all_subsets() {
        let (data, mut learner) = setup(2, 3, 10, 25);
        let mut seen = vec![false; 10];
        // 5 steps × (2 batches × 4) = 40 draws > 3 epochs of 10.
        for _ in 0..5 {
            for idx in learner.next_batch(data.len()) {
                seen[idx] = true;
            }
            for idx in learner.next_batch(data.len()) {
                seen[idx] = true;
            }
        }
        assert!(seen.iter().all(|&s| s), "epoch shuffling skipped subsets: {seen:?}");
    }

    #[test]
    fn batch_contraction_matches_sparse_theta_reference() {
        // The engine's direct minibatch accumulation must agree with the
        // sparse-Θ path it replaced (kept in dpp::likelihood as oracle).
        let (data, learner) = setup(3, 4, 20, 29);
        let (l1, l2) = learner.subkernels();
        let kernel = Kernel::Kron2(l1.clone(), l2.clone());
        let batch = [0usize, 3, 7, 7]; // repeat included
        let subsets: Vec<Vec<usize>> =
            batch.iter().map(|&i| data.subsets[i].clone()).collect();
        let theta =
            crate::dpp::likelihood::theta_sparse(&kernel, &subsets, 0.25).unwrap();
        let a1_ref = theta.block_trace(l2, 3, 4).unwrap();
        let a2_ref = theta.weighted_block_sum(l1, 3, 4).unwrap();
        let mut eng = ThetaEngine::new();
        let mut out = Matrix::zeros(0, 0);
        eng.contract_batch(
            KernelRef::Kron2(l1, l2),
            &data.subsets,
            &batch,
            0.25,
            Contraction::A1,
            &mut out,
        )
        .unwrap();
        assert!(out.rel_diff(&a1_ref) < 1e-12, "A1: {}", out.rel_diff(&a1_ref));
        eng.contract_batch(
            KernelRef::Kron2(l1, l2),
            &data.subsets,
            &batch,
            0.25,
            Contraction::A2,
            &mut out,
        )
        .unwrap();
        assert!(out.rel_diff(&a2_ref) < 1e-12, "A2: {}", out.rel_diff(&a2_ref));
    }

    #[test]
    fn minibatch_one_runs() {
        let (data, mut learner) = setup(2, 2, 20, 27);
        learner.minibatch = 1;
        for _ in 0..10 {
            learner.step(&data).unwrap();
        }
        let (l1, l2) = learner.subkernels();
        assert!(cholesky::is_pd(l1) && cholesky::is_pd(l2));
    }
}
