//! Subset clustering — the memory–time trade-off of §3.3.
//!
//! Partition the training set `{Y₁..Y_n} = ∪_k S_k` such that each part's
//! item-union stays below a budget `z` (Eq. 9). Each part's gradient block
//! `Θ_k = Σ_{Y∈S_k} U_Y L_Y⁻¹U_Yᵀ` then has at most `z²` non-zeros, so the
//! full-batch `Θ` is a sum of `m` sparse matrices stored in `O(mz² + N)`
//! instead of `O(N²)`.
//!
//! Finding the minimum `m` is a variant of the NP-hard Subset-Union
//! Knapsack Problem (SUKP, ref. [11]); the paper proposes the greedy
//! construction implemented here: each subset goes to the part whose union
//! it grows the least (ties → fullest part), opening a new part when no
//! part can absorb it within budget.

use crate::dpp::Kernel;
use crate::error::{Error, Result};
use crate::learn::stats::ThetaEngine;
use crate::linalg::{Matrix, SparseBuilder, SparseMatrix};
use std::collections::BTreeSet;

/// One part of the partition.
#[derive(Debug)]
pub struct Cluster {
    /// Indices into the training set.
    pub members: Vec<usize>,
    /// Union of member subsets.
    pub union: BTreeSet<usize>,
}

/// Greedy SUKP partition of `subsets` under union budget `z`.
/// Fails if any single subset already exceeds `z`.
pub fn greedy_partition(subsets: &[Vec<usize>], z: usize) -> Result<Vec<Cluster>> {
    // Largest-first placement: big subsets are hardest to place, and
    // placing them first measurably reduces part count vs arrival order.
    let mut order: Vec<usize> = (0..subsets.len()).collect();
    order.sort_by_key(|&i| std::cmp::Reverse(subsets[i].len()));
    let mut clusters: Vec<Cluster> = Vec::new();
    for &i in &order {
        let y = &subsets[i];
        if y.len() > z {
            return Err(Error::Invalid(format!(
                "subset {i} has {} items > budget z={z}",
                y.len()
            )));
        }
        // Find the cluster with minimal union growth that stays within z.
        let mut best: Option<(usize, usize, usize)> = None; // (growth, -fill, idx)
        for (c, cluster) in clusters.iter().enumerate() {
            let growth = y.iter().filter(|&&it| !cluster.union.contains(&it)).count();
            if cluster.union.len() + growth <= z {
                let fill = cluster.union.len();
                let key = (growth, usize::MAX - fill, c);
                if best.map(|b| key < b).unwrap_or(true) {
                    best = Some(key);
                }
            }
        }
        match best {
            Some((_, _, c)) => {
                clusters[c].members.push(i);
                clusters[c].union.extend(y.iter().copied());
            }
            None => {
                clusters.push(Cluster {
                    members: vec![i],
                    union: y.iter().copied().collect(),
                });
            }
        }
    }
    Ok(clusters)
}

/// Clustered Θ: one sparse block per part, summing to the full batch Θ.
pub struct ClusteredTheta {
    parts: Vec<SparseMatrix>,
    n1: usize,
    n2: usize,
}

impl ClusteredTheta {
    /// Build from a kernel and a clustered training set. Weights sum the
    /// parts to the batch mean `(1/n)Σ_i U_i L_{Y_i}⁻¹U_iᵀ`.
    pub fn build(
        kernel: &Kernel,
        subsets: &[Vec<usize>],
        clusters: &[Cluster],
        n1: usize,
        n2: usize,
    ) -> Result<Self> {
        let mut engine = ThetaEngine::new();
        Self::build_with(kernel, subsets, clusters, n1, n2, &mut engine)
    }

    /// [`ClusteredTheta::build`] with a caller-held [`ThetaEngine`]: every
    /// per-subset gather/factor/inverse runs in the engine's reused
    /// buffers, so rebuilding the clustered Θ each iteration only
    /// allocates the sparse parts themselves.
    pub fn build_with(
        kernel: &Kernel,
        subsets: &[Vec<usize>],
        clusters: &[Cluster],
        n1: usize,
        n2: usize,
        engine: &mut ThetaEngine,
    ) -> Result<Self> {
        let n = subsets.len().max(1) as f64;
        let mut parts = Vec::with_capacity(clusters.len());
        for cluster in clusters {
            let mut b = SparseBuilder::new(kernel.n());
            for &i in &cluster.members {
                let y = &subsets[i];
                if y.is_empty() {
                    continue;
                }
                let inv = engine.invert_subset_with(kernel, y)?;
                b.scatter_block(y, inv, 1.0 / n)?;
            }
            parts.push(b.build());
        }
        Ok(ClusteredTheta { parts, n1, n2 })
    }

    /// Number of parts `m`.
    pub fn parts(&self) -> usize {
        self.parts.len()
    }

    /// Total stored non-zeros (`≤ m·z²`).
    pub fn nnz(&self) -> usize {
        self.parts.iter().map(|p| p.nnz()).sum()
    }

    /// `A₁[k,l] = Tr(Θ_(kl)L₂)` summed over parts — `O(Σ nnz)`.
    pub fn block_trace(&self, l2: &Matrix) -> Result<Matrix> {
        let mut acc = Matrix::zeros(self.n1, self.n1);
        for p in &self.parts {
            acc += &p.block_trace(l2, self.n1, self.n2)?;
        }
        Ok(acc)
    }

    /// `A₂ = Σ_{ij} W[i,j]Θ_(ij)` summed over parts.
    pub fn weighted_block_sum(&self, w: &Matrix) -> Result<Matrix> {
        let mut acc = Matrix::zeros(self.n2, self.n2);
        for p in &self.parts {
            acc += &p.weighted_block_sum(w, self.n1, self.n2)?;
        }
        Ok(acc)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dpp::likelihood::theta_dense;
    use crate::linalg::kron;
    use crate::rng::Rng;

    fn random_subsets(n: usize, count: usize, kmax: usize, seed: u64) -> Vec<Vec<usize>> {
        let mut rng = Rng::new(seed);
        (0..count)
            .map(|_| {
                let k = rng.int_range(1, kmax);
                rng.subset(n, k)
            })
            .collect()
    }

    #[test]
    fn partition_covers_all_exactly_once() {
        let subsets = random_subsets(40, 30, 8, 1);
        let clusters = greedy_partition(&subsets, 15).unwrap();
        let mut seen = vec![0usize; 30];
        for c in &clusters {
            for &i in &c.members {
                seen[i] += 1;
            }
        }
        assert!(seen.iter().all(|&s| s == 1), "not a partition: {seen:?}");
    }

    #[test]
    fn unions_respect_budget() {
        let subsets = random_subsets(50, 40, 10, 2);
        let z = 18;
        let clusters = greedy_partition(&subsets, z).unwrap();
        for c in &clusters {
            assert!(c.union.len() <= z, "union {} > z={z}", c.union.len());
            // Union really is the union of members.
            let mut expect = BTreeSet::new();
            for &i in &c.members {
                expect.extend(subsets[i].iter().copied());
            }
            assert_eq!(c.union, expect);
        }
    }

    #[test]
    fn oversized_subset_rejected() {
        let subsets = vec![(0..10).collect::<Vec<usize>>()];
        assert!(greedy_partition(&subsets, 5).is_err());
    }

    #[test]
    fn greedy_merges_overlapping_subsets() {
        // Heavily-overlapping subsets should share parts.
        let subsets = vec![
            vec![0, 1, 2],
            vec![1, 2, 3],
            vec![0, 2, 3],
            vec![10, 11, 12],
            vec![11, 12, 13],
        ];
        let clusters = greedy_partition(&subsets, 5).unwrap();
        assert!(clusters.len() <= 2, "expected ≤2 parts, got {}", clusters.len());
    }

    #[test]
    fn clustered_theta_matches_dense() {
        let mut rng = Rng::new(3);
        let l1 = {
            let mut m = rng.paper_init_kernel(3);
            m.add_diag_mut(0.5);
            m
        };
        let l2 = {
            let mut m = rng.paper_init_kernel(4);
            m.add_diag_mut(0.5);
            m
        };
        let kernel = Kernel::Kron2(l1.clone(), l2.clone());
        let subsets = random_subsets(12, 15, 5, 4);
        let clusters = greedy_partition(&subsets, 9).unwrap();
        let ct = ClusteredTheta::build(&kernel, &subsets, &clusters, 3, 4).unwrap();
        let dense = theta_dense(&kernel, &subsets).unwrap();
        // A1 contraction matches dense path.
        let a1_fast = ct.block_trace(&l2).unwrap();
        let a1_dense = kron::block_trace(&dense, &l2, 3, 4).unwrap();
        assert!(a1_fast.rel_diff(&a1_dense) < 1e-10);
        // A2 contraction matches dense path.
        let a2_fast = ct.weighted_block_sum(&l1).unwrap();
        let a2_dense = kron::weighted_block_sum(&dense, &l1, 3, 4).unwrap();
        assert!(a2_fast.rel_diff(&a2_dense) < 1e-10);
    }

    #[test]
    fn memory_bound_holds() {
        let subsets = random_subsets(100, 50, 6, 5);
        let z = 20;
        let clusters = greedy_partition(&subsets, z).unwrap();
        let m = clusters.len();
        // nnz ≤ m·z² by Eq. 9's sparsity argument.
        let mut rng = Rng::new(6);
        let l1 = {
            let mut k = rng.paper_init_kernel(10);
            k.add_diag_mut(0.5);
            k
        };
        let l2 = {
            let mut k = rng.paper_init_kernel(10);
            k.add_diag_mut(0.5);
            k
        };
        let kernel = Kernel::Kron2(l1, l2);
        let ct = ClusteredTheta::build(&kernel, &subsets, &clusters, 10, 10).unwrap();
        assert!(ct.nnz() <= m * z * z, "nnz {} > m·z² = {}", ct.nnz(), m * z * z);
    }
}
