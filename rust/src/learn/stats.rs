//! Compressed training statistics — the Θ-free gradient engine.
//!
//! The batch learners' gradient statistics are
//! `Θ = (1/n) Σᵢ Uᵢ L_{Yᵢ}⁻¹ Uᵢᵀ` (Eq. 4) feeding block contractions that
//! are *linear* in Θ (App. B): `A₁[k,l] = Tr(Θ_(kl)·L₂)` and
//! `A₂ = Σ_{ij} L₁[i,j]·Θ_(ij)`. Materializing the dense `N×N` Θ just to
//! contract it costs `O(N²)` time and space per half-update; this module
//! accumulates the contractions *directly from the `κ×κ` subset inverses*
//! in `O(nκ²)` — the same observation behind the paper's sparse/stochastic
//! updates (§3.2–3.3) — so the batch step drops from
//! `O(nκ³ + N²)` time / `O(N²)` extra space to
//! `O(nκ³ + nκ² + N₁³ + N₂³)` time / `O(nκ + N₁² + N₂²)` extra space, and
//! learning works at ground-set sizes where an `N×N` Θ does not fit.
//!
//! Two pieces:
//!
//! - [`CompressedTraining`]: built once per training set — sorts and
//!   deduplicates identical subsets into multiplicity weights (real basket
//!   data repeats subsets; dedup shrinks the effective `n`) and flattens
//!   the indices into a CSR-style arena with *precomputed* Kronecker index
//!   splits `t ↦ (k, p)` (m = 2) / `(k, p, q)` (m = 3), so the
//!   per-iteration sweep is cache-linear with no divisions in the inner
//!   loops.
//! - [`ThetaEngine`]: one parallel sweep per half-update that gathers each
//!   `L_Y`, Cholesky-factors it once, and accumulates the requested
//!   contraction into per-stripe sub-kernel-sized partials with a fixed
//!   subset→stripe map and ordered reduction — bitwise invariant to the
//!   worker-thread count. The same factorization is fused to also return
//!   `Σᵢ wᵢ·log det L_{Yᵢ}`, so objective tracking costs no extra
//!   factorizations. All state lives in engine-held scratch: steady-state
//!   sweeps are allocation-free (asserted by `tests/alloc_free.rs`).
//!
//! The dense [`crate::dpp::likelihood::theta_dense`] remains as the test
//! oracle; the engine-vs-oracle property suite lives in
//! `tests/learning_stats.rs`, and the dense-Θ-vs-engine speedups land in
//! `BENCH_learning.json` (see EXPERIMENTS.md §Learning).

use crate::error::{Error, Result};
use crate::linalg::{cholesky, matmul, Matrix};

/// Number of deterministic accumulation stripes. Unique subset `u` belongs
/// to stripe `u % STRIPES` and is processed in ascending `u` within its
/// stripe; each stripe owns its own partial accumulator and the final
/// reduction sums stripes in ascending order. Workers own whole stripes,
/// so the result is bitwise identical for **any** worker count (including
/// the inline single-thread path).
const STRIPES: usize = 16;

/// Below this many unique subsets the sweep runs inline: thread spawns
/// allocate and cost more than they save on small corpora (and the
/// counting-allocator suite measures this regime).
const PAR_MIN_SUBSETS: usize = 48;

/// Kernel structure a [`CompressedTraining`] is built for; the index
/// splits of the arena are precomputed against these factor sizes.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum KernelShape {
    /// Unstructured kernel over `n` items.
    Full { n: usize },
    /// `L₁ ⊗ L₂`; item `t = k·n2 + p` (§2 index split).
    Kron2 { n1: usize, n2: usize },
    /// `L₁ ⊗ L₂ ⊗ L₃`; item `t = (k·n2 + p)·n3 + q`.
    Kron3 { n1: usize, n2: usize, n3: usize },
}

impl KernelShape {
    /// Ground-set size `N`.
    pub fn ground_size(&self) -> usize {
        match *self {
            KernelShape::Full { n } => n,
            KernelShape::Kron2 { n1, n2 } => n1 * n2,
            KernelShape::Kron3 { n1, n2, n3 } => n1 * n2 * n3,
        }
    }
}

/// Borrowed kernel factors — what the engine reads entries from. Learners
/// pass their sub-kernels directly, avoiding the per-step `Kernel` clone.
#[derive(Clone, Copy)]
pub enum KernelRef<'a> {
    /// Dense `L`.
    Full(&'a Matrix),
    /// `L₁ ⊗ L₂`.
    Kron2(&'a Matrix, &'a Matrix),
    /// `L₁ ⊗ L₂ ⊗ L₃`.
    Kron3(&'a Matrix, &'a Matrix, &'a Matrix),
}

impl KernelRef<'_> {
    /// The [`KernelShape`] these factors define.
    pub fn shape(&self) -> KernelShape {
        match *self {
            KernelRef::Full(l) => KernelShape::Full { n: l.rows() },
            KernelRef::Kron2(a, b) => KernelShape::Kron2 { n1: a.rows(), n2: b.rows() },
            KernelRef::Kron3(a, b, c) => {
                KernelShape::Kron3 { n1: a.rows(), n2: b.rows(), n3: c.rows() }
            }
        }
    }
}

/// Which App.-B block contraction to accumulate.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Contraction {
    /// First factor: `A₁[k,l] = Tr(Θ_(kl)·B)` with `B` the remaining
    /// factor(s). For m = 3 the grouped `B = L₂ ⊗ L₃` is *not*
    /// materialized — its entries factor along the index split.
    A1,
    /// Middle factor (m = 3 only): the mixed weighted trace `H` of
    /// [`crate::linalg::kron::mixed_weighted_trace`] with `W₁ = L₁`,
    /// `W₃ = L₃`.
    Mid,
    /// Last factor: `A₂ = Σ_{ij} W[i,j]·Θ_(ij)` with `W` the leading
    /// factor(s) (grouped `W = L₁ ⊗ L₂` for m = 3, never materialized).
    A2,
}

/// A training set compressed for the Θ-free sweep: duplicate subsets
/// merged into multiplicity weights, indices flattened into a CSR-style
/// arena, Kronecker index splits precomputed.
pub struct CompressedTraining {
    shape: KernelShape,
    /// Arena offsets; unique subset `u` occupies `items[offsets[u]..offsets[u+1]]`.
    offsets: Vec<usize>,
    /// Flat ground-set item ids (sorted within each subset).
    items: Vec<usize>,
    /// Factor-1 index `k` per arena slot (empty for [`KernelShape::Full`]).
    s1: Vec<u32>,
    /// Factor-2 index `p` per arena slot (empty for `Full`).
    s2: Vec<u32>,
    /// Factor-3 index `q` per arena slot (`Kron3` only).
    s3: Vec<u32>,
    /// `multiplicity / n` per unique subset — the Θ mean weights.
    weights: Vec<f64>,
    /// Multiplicity counts.
    counts: Vec<u32>,
    /// Original (pre-dedup) subset count, including empty subsets.
    n_total: usize,
    /// Largest subset size κ.
    kappa: usize,
    fingerprint: u64,
}

impl CompressedTraining {
    /// Build from a subset list. Subsets must be sorted and duplicate-free
    /// (as [`crate::learn::traits::TrainingSet`] guarantees) with items in
    /// range for `shape`. `O(n log n + nκ)`.
    pub fn new(subsets: &[Vec<usize>], shape: KernelShape) -> Result<Self> {
        let n_items = shape.ground_size();
        for (k, y) in subsets.iter().enumerate() {
            if y.windows(2).any(|w| w[0] >= w[1]) {
                return Err(Error::Invalid(format!(
                    "compressed stats: subset {k} is not sorted/unique"
                )));
            }
            if let Some(&last) = y.last() {
                if last >= n_items {
                    return Err(Error::Invalid(format!(
                        "compressed stats: subset {k} references item {last} ≥ N={n_items}"
                    )));
                }
            }
        }
        // Sort subset indices by content; equal runs collapse to one arena
        // entry with a multiplicity count.
        let mut order: Vec<usize> =
            (0..subsets.len()).filter(|&i| !subsets[i].is_empty()).collect();
        order.sort_by(|&a, &b| subsets[a].cmp(&subsets[b]));
        let mut offsets = vec![0usize];
        let mut items: Vec<usize> = Vec::new();
        let mut counts: Vec<u32> = Vec::new();
        let mut kappa = 0usize;
        let mut i = 0;
        while i < order.len() {
            let y = &subsets[order[i]];
            let mut j = i + 1;
            while j < order.len() && subsets[order[j]] == *y {
                j += 1;
            }
            items.extend_from_slice(y);
            offsets.push(items.len());
            counts.push((j - i) as u32);
            kappa = kappa.max(y.len());
            i = j;
        }
        // Precomputed index splits: the sweep's inner loops never divide.
        let (mut s1, mut s2, mut s3) = (Vec::new(), Vec::new(), Vec::new());
        match shape {
            KernelShape::Full { .. } => {}
            KernelShape::Kron2 { n2, .. } => {
                s1.reserve(items.len());
                s2.reserve(items.len());
                for &t in &items {
                    let (k, p) = split_item2(t, n2);
                    s1.push(k);
                    s2.push(p);
                }
            }
            KernelShape::Kron3 { n2, n3, .. } => {
                s1.reserve(items.len());
                s2.reserve(items.len());
                s3.reserve(items.len());
                for &t in &items {
                    let (k, p, q) = split_item3(t, n2, n3);
                    s1.push(k);
                    s2.push(p);
                    s3.push(q);
                }
            }
        }
        let n_total = subsets.len();
        let weights =
            counts.iter().map(|&c| c as f64 / n_total.max(1) as f64).collect();
        Ok(CompressedTraining {
            shape,
            offsets,
            items,
            s1,
            s2,
            s3,
            weights,
            counts,
            n_total,
            kappa,
            fingerprint: Self::fingerprint_of(subsets),
        })
    }

    /// Number of unique non-empty subsets.
    pub fn unique(&self) -> usize {
        self.counts.len()
    }

    /// Original subset count `n` (the Θ mean denominator).
    pub fn n_total(&self) -> usize {
        self.n_total
    }

    /// Largest subset size κ.
    pub fn kappa(&self) -> usize {
        self.kappa
    }

    /// Shape the splits were precomputed for.
    pub fn shape(&self) -> KernelShape {
        self.shape
    }

    /// `(non-empty subsets) / unique` — the factor dedup shrinks the sweep by.
    pub fn dedup_ratio(&self) -> f64 {
        let nonempty: u64 = self.counts.iter().map(|&c| c as u64).sum();
        nonempty as f64 / self.unique().max(1) as f64
    }

    /// Items of unique subset `u`.
    pub fn subset(&self, u: usize) -> &[usize] {
        &self.items[self.offsets[u]..self.offsets[u + 1]]
    }

    /// Mean weight (`multiplicity / n`) of unique subset `u`.
    pub fn weight(&self, u: usize) -> f64 {
        self.weights[u]
    }

    /// Arena range of unique subset `u`.
    fn range(&self, u: usize) -> (usize, usize) {
        (self.offsets[u], self.offsets[u + 1])
    }

    /// Split-index slices for arena range `[lo, hi)` (empty for factors the
    /// shape does not have).
    fn splits(&self, lo: usize, hi: usize) -> (&[u32], &[u32], &[u32]) {
        (
            if self.s1.is_empty() { &[] } else { &self.s1[lo..hi] },
            if self.s2.is_empty() { &[] } else { &self.s2[lo..hi] },
            if self.s3.is_empty() { &[] } else { &self.s3[lo..hi] },
        )
    }

    /// Does this compression still describe `subsets`? An `O(nκ)`
    /// allocation-free fingerprint pass — the learners' per-step
    /// rebuild-on-change check.
    pub fn matches(&self, subsets: &[Vec<usize>]) -> bool {
        self.n_total == subsets.len() && self.fingerprint == Self::fingerprint_of(subsets)
    }

    /// Order-sensitive FNV-1a over subset lengths and items.
    pub fn fingerprint_of(subsets: &[Vec<usize>]) -> u64 {
        let mut h: u64 = 0xcbf2_9ce4_8422_2325;
        let mut mix = |v: u64| {
            h ^= v;
            h = h.wrapping_mul(0x0000_0100_0000_01b3);
        };
        for y in subsets {
            mix(y.len() as u64 ^ 0x9e37_79b9_7f4a_7c15);
            for &i in y {
                mix(i as u64 + 1);
            }
        }
        h
    }
}

/// Rebuild-on-change cache for a learner-held [`CompressedTraining`]: the
/// cheap fingerprint pass detects training-set changes; the arena is
/// rebuilt only when the data (or the kernel shape) actually changed, so
/// steady-state steps never allocate here.
#[derive(Default)]
pub struct StatsCache {
    stats: Option<CompressedTraining>,
}

impl StatsCache {
    /// Current compression of `subsets` for `shape`, rebuilding if stale.
    pub fn get(
        &mut self,
        subsets: &[Vec<usize>],
        shape: KernelShape,
    ) -> Result<&CompressedTraining> {
        let stale = match &self.stats {
            Some(s) => s.shape() != shape || !s.matches(subsets),
            None => true,
        };
        if stale {
            self.stats = Some(CompressedTraining::new(subsets, shape)?);
        }
        Ok(self.stats.as_ref().expect("just ensured"))
    }
}

/// `log det(L₁⊗L₂ + I) = Σ_{k,r} ln(1 + d₁ₖ·d₂ᵣ)` from sub-spectra — the
/// Eq.-3 normalizer without touching the product space (Cor. 2.2).
pub fn logdet_lpi_kron2(d1: &[f64], d2: &[f64]) -> Result<f64> {
    let mut s = 0.0;
    for &x in d1 {
        for &y in d2 {
            let v = 1.0 + x * y;
            if v <= 0.0 {
                return Err(Error::Numerical("logdet(L+I): non-PD Kron spectrum".into()));
            }
            s += v.ln();
        }
    }
    Ok(s)
}

/// Three-factor form of [`logdet_lpi_kron2`].
pub fn logdet_lpi_kron3(d1: &[f64], d2: &[f64], d3: &[f64]) -> Result<f64> {
    let mut s = 0.0;
    for &x in d1 {
        for &y in d2 {
            let xy = x * y;
            for &z in d3 {
                let v = 1.0 + xy * z;
                if v <= 0.0 {
                    return Err(Error::Numerical(
                        "logdet(L+I): non-PD Kron spectrum".into(),
                    ));
                }
                s += v.ln();
            }
        }
    }
    Ok(s)
}

/// The Θ-free sweep engine: per-stripe partials, gather/factor/inverse
/// scratch, and the inverse pool of the dense-Θ compatibility path — all
/// reused across iterations, so steady-state sweeps are allocation-free.
pub struct ThetaEngine {
    /// Worker-thread cap (0 = [`matmul::available_threads`]). Results are
    /// bitwise identical for every cap — the knob exists for the
    /// determinism tests and for embedding in already-parallel callers.
    thread_cap: usize,
    /// Per-stripe contraction partials (sub-kernel sized).
    partials: Vec<Matrix>,
    /// Per-stripe fused `Σ w·logdet` partials.
    logdets: Vec<f64>,
    /// Per-stripe `L_Y` gather buffers.
    subs: Vec<Matrix>,
    /// Per-stripe Cholesky factor buffers.
    chols: Vec<Matrix>,
    /// Per-stripe triangular-inverse buffers.
    tris: Vec<Matrix>,
    /// Per-stripe `L_Y⁻¹` buffers.
    invs: Vec<Matrix>,
    /// Per-unique-subset inverses of the dense-Θ path (Picard/Joint).
    inv_pool: Vec<Matrix>,
    /// Per-unique-subset weighted logdets (summed in `u` order).
    pool_logdets: Vec<f64>,
    /// Minibatch split scratch (the stochastic path has no precomputed splits).
    b1: Vec<u32>,
    b2: Vec<u32>,
    b3: Vec<u32>,
}

impl Default for ThetaEngine {
    fn default() -> Self {
        Self::new()
    }
}

impl ThetaEngine {
    pub fn new() -> Self {
        let mats = || (0..STRIPES).map(|_| Matrix::zeros(0, 0)).collect::<Vec<_>>();
        ThetaEngine {
            thread_cap: 0,
            partials: mats(),
            logdets: vec![0.0; STRIPES],
            subs: mats(),
            chols: mats(),
            tris: mats(),
            invs: mats(),
            inv_pool: Vec::new(),
            pool_logdets: Vec::new(),
            b1: Vec::new(),
            b2: Vec::new(),
            b3: Vec::new(),
        }
    }

    /// Cap worker threads (0 restores the [`matmul::available_threads`]
    /// default). Purely a scheduling knob: every cap produces bitwise
    /// identical results.
    pub fn set_thread_cap(&mut self, cap: usize) {
        self.thread_cap = cap;
    }

    fn workers(&self, unique: usize) -> usize {
        if unique < PAR_MIN_SUBSETS {
            return 1;
        }
        let cap = if self.thread_cap == 0 {
            matmul::available_threads()
        } else {
            self.thread_cap
        };
        cap.min(STRIPES).max(1)
    }

    /// One fused sweep: gather each unique `L_Y`, factor once, accumulate
    /// contraction `op` into `out` (resized to the factor's size), and
    /// return `Σᵢ wᵢ·log det L_{Yᵢ}`. `O(nκ³ + nκ²)`, never touches the
    /// product space; bitwise thread-count-invariant; allocation-free in
    /// steady state.
    pub fn contract(
        &mut self,
        kernel: KernelRef<'_>,
        stats: &CompressedTraining,
        op: Contraction,
        out: &mut Matrix,
    ) -> Result<f64> {
        check_shape(kernel, stats)?;
        let dim = contraction_dim(kernel, op)?;
        out.resize_zeroed(dim, dim);
        for p in &mut self.partials {
            p.resize_zeroed(dim, dim);
        }
        self.logdets.fill(0.0);
        let nworkers = self.workers(stats.unique());
        self.run_stripes(kernel, stats, Some(op), nworkers)?;
        let mut total = 0.0;
        for s in 0..STRIPES {
            *out += &self.partials[s];
            total += self.logdets[s];
        }
        Ok(total)
    }

    /// Logdet-only sweep: `Σᵢ wᵢ·log det L_{Yᵢ}` (the Eq.-3 data term)
    /// without computing inverses — the fused objective path. Parallel,
    /// deduplicated, allocation-free, bitwise thread-count-invariant.
    pub fn sum_logdet(
        &mut self,
        kernel: KernelRef<'_>,
        stats: &CompressedTraining,
    ) -> Result<f64> {
        check_shape(kernel, stats)?;
        self.logdets.fill(0.0);
        let nworkers = self.workers(stats.unique());
        self.run_stripes(kernel, stats, None, nworkers)?;
        Ok(self.logdets.iter().sum())
    }

    fn run_stripes(
        &mut self,
        kernel: KernelRef<'_>,
        stats: &CompressedTraining,
        op: Option<Contraction>,
        nworkers: usize,
    ) -> Result<()> {
        if nworkers <= 1 {
            for s in 0..STRIPES {
                stripe_sweep(
                    kernel,
                    stats,
                    op,
                    s,
                    &mut self.partials[s],
                    &mut self.subs[s],
                    &mut self.chols[s],
                    &mut self.tris[s],
                    &mut self.invs[s],
                    &mut self.logdets[s],
                )?;
            }
            return Ok(());
        }
        // Workers own whole stripes (contiguous blocks — which worker runs
        // a stripe never affects that stripe's arithmetic).
        let per = STRIPES.div_ceil(nworkers);
        let ThetaEngine { partials, subs, chols, tris, invs, logdets, .. } = self;
        std::thread::scope(|sc| -> Result<()> {
            let mut handles = Vec::new();
            let (mut pr, mut sr, mut cr, mut tr, mut ir, mut lr) = (
                &mut partials[..],
                &mut subs[..],
                &mut chols[..],
                &mut tris[..],
                &mut invs[..],
                &mut logdets[..],
            );
            let mut start = 0usize;
            while start < STRIPES {
                let take = per.min(STRIPES - start);
                let (p, rest) = pr.split_at_mut(take);
                pr = rest;
                let (sb, rest) = sr.split_at_mut(take);
                sr = rest;
                let (cb, rest) = cr.split_at_mut(take);
                cr = rest;
                let (tb, rest) = tr.split_at_mut(take);
                tr = rest;
                let (ib, rest) = ir.split_at_mut(take);
                ir = rest;
                let (lb, rest) = lr.split_at_mut(take);
                lr = rest;
                let lo = start;
                handles.push(sc.spawn(move || -> Result<()> {
                    for off in 0..take {
                        stripe_sweep(
                            kernel,
                            stats,
                            op,
                            lo + off,
                            &mut p[off],
                            &mut sb[off],
                            &mut cb[off],
                            &mut tb[off],
                            &mut ib[off],
                            &mut lb[off],
                        )?;
                    }
                    Ok(())
                }));
                start += take;
            }
            matmul::join_first_error(handles)
        })
    }

    /// Dense Θ for the full-kernel Picard / Joint-Picard paths:
    /// deduplicated subset inverses (phase 1, contiguous chunks into the
    /// engine's inverse pool) scattered by disjoint Θ row panels (phase 2)
    /// — no serial scatter, no `Mutex` recollection, deterministic for any
    /// worker count (each Θ row is owned by exactly one worker and receives
    /// its contributions in unique-subset order). Returns the fused
    /// `Σᵢ wᵢ·log det L_{Yᵢ}`.
    pub fn theta_dense_into(
        &mut self,
        kernel: KernelRef<'_>,
        stats: &CompressedTraining,
        out: &mut Matrix,
    ) -> Result<f64> {
        check_shape(kernel, stats)?;
        let n = stats.shape().ground_size();
        let unique = stats.unique();
        if self.inv_pool.len() < unique {
            self.inv_pool.resize_with(unique, || Matrix::zeros(0, 0));
        }
        self.pool_logdets.clear();
        self.pool_logdets.resize(unique, 0.0);
        let nworkers = self.workers(unique);
        // Phase 1: pool the κ×κ inverses (slots are independent, so any
        // contiguous partition is deterministic).
        {
            let ThetaEngine { subs, chols, tris, inv_pool, pool_logdets, .. } = self;
            if nworkers <= 1 {
                pool_range(
                    kernel,
                    stats,
                    0,
                    &mut subs[0],
                    &mut chols[0],
                    &mut tris[0],
                    &mut inv_pool[..unique],
                    &mut pool_logdets[..],
                )?;
            } else {
                let chunk = unique.div_ceil(nworkers);
                std::thread::scope(|sc| -> Result<()> {
                    let mut handles = Vec::new();
                    let mut ip = &mut inv_pool[..unique];
                    let mut pl = &mut pool_logdets[..];
                    let mut sr = &mut subs[..];
                    let mut cr = &mut chols[..];
                    let mut tr = &mut tris[..];
                    let mut base = 0usize;
                    while base < unique {
                        let take = chunk.min(unique - base);
                        let (ipc, rest) = ip.split_at_mut(take);
                        ip = rest;
                        let (plc, rest) = pl.split_at_mut(take);
                        pl = rest;
                        let (sb, rest) = sr.split_at_mut(1);
                        sr = rest;
                        let (cb, rest) = cr.split_at_mut(1);
                        cr = rest;
                        let (tb, rest) = tr.split_at_mut(1);
                        tr = rest;
                        let lo = base;
                        handles.push(sc.spawn(move || {
                            pool_range(
                                kernel,
                                stats,
                                lo,
                                &mut sb[0],
                                &mut cb[0],
                                &mut tb[0],
                                ipc,
                                plc,
                            )
                        }));
                        base += take;
                    }
                    matmul::join_first_error(handles)
                })?;
            }
        }
        // Fused data term, reduced in ascending unique-subset order.
        let total: f64 = self.pool_logdets.iter().sum();
        // Phase 2: row-panel scatter.
        out.resize_zeroed(n, n);
        if nworkers <= 1 || n < nworkers {
            scatter_rows(stats, &self.inv_pool, 0, n, out.as_mut_slice(), n);
        } else {
            let band = n.div_ceil(nworkers);
            let inv_pool = &self.inv_pool;
            std::thread::scope(|sc| {
                let mut rest = out.as_mut_slice();
                let mut lo = 0usize;
                while lo < n {
                    let len = band.min(n - lo);
                    let (chunk, tail) = rest.split_at_mut(len * n);
                    rest = tail;
                    let start = lo;
                    sc.spawn(move || {
                        scatter_rows(stats, inv_pool, start, start + len, chunk, n)
                    });
                    lo += len;
                }
            });
        }
        Ok(total)
    }

    /// Minibatch contraction without precomputed splits (the stochastic
    /// learner's batch changes every step): `O(|B|κ³ + |B|κ²)` straight
    /// from the subset inverses — no sparse Θ, no subset clones. Serial
    /// (minibatches are tiny) and trivially deterministic. Returns
    /// `weight·Σ_{i∈B} log det L_{Yᵢ}`.
    pub fn contract_batch(
        &mut self,
        kernel: KernelRef<'_>,
        subsets: &[Vec<usize>],
        batch: &[usize],
        weight: f64,
        op: Contraction,
        out: &mut Matrix,
    ) -> Result<f64> {
        let dim = contraction_dim(kernel, op)?;
        let n = kernel.shape().ground_size();
        out.resize_zeroed(dim, dim);
        let mut total = 0.0;
        for &bi in batch {
            let y = subsets.get(bi).ok_or_else(|| {
                Error::Invalid(format!("contract_batch: index {bi} out of range"))
            })?;
            if y.is_empty() {
                continue;
            }
            if y.iter().any(|&t| t >= n) {
                return Err(Error::Invalid(format!(
                    "contract_batch: subset {bi} references an item ≥ N={n}"
                )));
            }
            split_indices(kernel, y, &mut self.b1, &mut self.b2, &mut self.b3);
            gather_subset(kernel, y, &self.b1, &self.b2, &self.b3, &mut self.subs[0]);
            cholesky::Cholesky::factor_into(&self.subs[0], &mut self.chols[0])?;
            let mut ld = 0.0;
            for i in 0..y.len() {
                ld += self.chols[0].get(i, i).ln();
            }
            total += weight * 2.0 * ld;
            cholesky::inverse_from_factor_into(
                &self.chols[0],
                &mut self.tris[0],
                &mut self.invs[0],
            );
            accumulate(kernel, op, weight, &self.invs[0], &self.b1, &self.b2, &self.b3, out);
        }
        Ok(total)
    }

    /// Factor + invert one `L_Y` entirely in engine-held buffers — the
    /// §3.3 clustering builder's per-subset path.
    pub fn invert_subset_with(
        &mut self,
        kernel: &crate::dpp::Kernel,
        y: &[usize],
    ) -> Result<&Matrix> {
        kernel.principal_submatrix_into(y, &mut self.subs[0]);
        cholesky::Cholesky::factor_into(&self.subs[0], &mut self.chols[0])?;
        cholesky::inverse_from_factor_into(
            &self.chols[0],
            &mut self.tris[0],
            &mut self.invs[0],
        );
        Ok(&self.invs[0])
    }
}

/// Output size of contraction `op` against `kernel` (validates the combo).
fn contraction_dim(kernel: KernelRef<'_>, op: Contraction) -> Result<usize> {
    match (kernel, op) {
        (KernelRef::Kron2(l1, _), Contraction::A1) => Ok(l1.rows()),
        (KernelRef::Kron2(_, l2), Contraction::A2) => Ok(l2.rows()),
        (KernelRef::Kron2(..), Contraction::Mid) => Err(Error::Invalid(
            "contraction Mid requires a three-factor kernel".into(),
        )),
        (KernelRef::Kron3(l1, _, _), Contraction::A1) => Ok(l1.rows()),
        (KernelRef::Kron3(_, l2, _), Contraction::Mid) => Ok(l2.rows()),
        (KernelRef::Kron3(_, _, l3), Contraction::A2) => Ok(l3.rows()),
        (KernelRef::Full(_), _) => Err(Error::Invalid(
            "full kernels have no block contraction — use theta_dense_into".into(),
        )),
    }
}

fn check_shape(kernel: KernelRef<'_>, stats: &CompressedTraining) -> Result<()> {
    if kernel.shape() != stats.shape() {
        return Err(Error::Shape(format!(
            "compressed stats built for {:?}, kernel is {:?}",
            stats.shape(),
            kernel.shape()
        )));
    }
    Ok(())
}

/// Sweep one stripe: unique subsets `u ≡ stripe (mod STRIPES)` in
/// ascending `u`, accumulating into this stripe's own partial — the unit
/// of the thread-count-invariance guarantee.
#[allow(clippy::too_many_arguments)]
fn stripe_sweep(
    kernel: KernelRef<'_>,
    stats: &CompressedTraining,
    op: Option<Contraction>,
    stripe: usize,
    partial: &mut Matrix,
    sub: &mut Matrix,
    chol: &mut Matrix,
    tri: &mut Matrix,
    inv: &mut Matrix,
    logdet: &mut f64,
) -> Result<()> {
    let mut u = stripe;
    while u < stats.unique() {
        let (lo, hi) = stats.range(u);
        let w = stats.weight(u);
        let (s1, s2, s3) = stats.splits(lo, hi);
        let items = &stats.items[lo..hi];
        gather_subset(kernel, items, s1, s2, s3, sub);
        cholesky::Cholesky::factor_into(sub, chol)?;
        let mut ld = 0.0;
        for i in 0..items.len() {
            ld += chol.get(i, i).ln();
        }
        *logdet += w * 2.0 * ld;
        if let Some(op) = op {
            cholesky::inverse_from_factor_into(chol, tri, inv);
            accumulate(kernel, op, w, inv, s1, s2, s3, partial);
        }
        u += STRIPES;
    }
    Ok(())
}

/// Gather `L_Y` into `sub` from kernel factors and precomputed splits —
/// `O(κ²)` with no divisions.
fn gather_subset(
    kernel: KernelRef<'_>,
    items: &[usize],
    s1: &[u32],
    s2: &[u32],
    s3: &[u32],
    sub: &mut Matrix,
) {
    let k = items.len();
    sub.resize_zeroed(k, k);
    match kernel {
        KernelRef::Full(l) => {
            for a in 0..k {
                let src = l.row(items[a]);
                let dst = sub.row_mut(a);
                for (d, &j) in dst.iter_mut().zip(items) {
                    *d = src[j];
                }
            }
        }
        KernelRef::Kron2(l1, l2) => {
            for a in 0..k {
                let r1 = l1.row(s1[a] as usize);
                let r2 = l2.row(s2[a] as usize);
                let dst = sub.row_mut(a);
                for b in 0..k {
                    dst[b] = r1[s1[b] as usize] * r2[s2[b] as usize];
                }
            }
        }
        KernelRef::Kron3(l1, l2, l3) => {
            for a in 0..k {
                let r1 = l1.row(s1[a] as usize);
                let r2 = l2.row(s2[a] as usize);
                let r3 = l3.row(s3[a] as usize);
                let dst = sub.row_mut(a);
                for b in 0..k {
                    dst[b] = r1[s1[b] as usize] * r2[s2[b] as usize] * r3[s3[b] as usize];
                }
            }
        }
    }
}

/// Accumulate one subset's `w·inv` into the requested contraction — the
/// O(κ²) core replacing the O(N²) dense scatter-then-contract. Derivation
/// (App. B): Θ[t_a, t_b] += w·inv[a,b] with `t = (k, p(, q))`, and each
/// contraction is linear in Θ, so the Θ entry's coefficient lands directly:
///
/// - `A₁[k_a, k_b] += w·inv[a,b]·L₂[p_b, p_a]` (× `L₃[q_b, q_a]` grouped),
/// - `H [p_a, p_b] += w·inv[a,b]·L₁[k_b, k_a]·L₃[q_b, q_a]`,
/// - `A₂[p_a, p_b] += w·inv[a,b]·L₁[k_a, k_b]`
///   (m = 3: `A₂[q_a, q_b] += w·inv[a,b]·L₁[k_a, k_b]·L₂[p_a, p_b]`).
#[allow(clippy::too_many_arguments)]
fn accumulate(
    kernel: KernelRef<'_>,
    op: Contraction,
    w: f64,
    inv: &Matrix,
    s1: &[u32],
    s2: &[u32],
    s3: &[u32],
    out: &mut Matrix,
) {
    let k = inv.rows();
    match (kernel, op) {
        (KernelRef::Kron2(_, l2), Contraction::A1) => {
            for a in 0..k {
                let iv = inv.row(a);
                let pa = s2[a] as usize;
                let orow = out.row_mut(s1[a] as usize);
                for b in 0..k {
                    orow[s1[b] as usize] += w * iv[b] * l2.get(s2[b] as usize, pa);
                }
            }
        }
        (KernelRef::Kron2(l1, _), Contraction::A2) => {
            for a in 0..k {
                let iv = inv.row(a);
                let ka = s1[a] as usize;
                let orow = out.row_mut(s2[a] as usize);
                for b in 0..k {
                    orow[s2[b] as usize] += w * iv[b] * l1.get(ka, s1[b] as usize);
                }
            }
        }
        (KernelRef::Kron3(_, l2, l3), Contraction::A1) => {
            for a in 0..k {
                let iv = inv.row(a);
                let (pa, qa) = (s2[a] as usize, s3[a] as usize);
                let orow = out.row_mut(s1[a] as usize);
                for b in 0..k {
                    orow[s1[b] as usize] += w
                        * iv[b]
                        * l2.get(s2[b] as usize, pa)
                        * l3.get(s3[b] as usize, qa);
                }
            }
        }
        (KernelRef::Kron3(l1, _, l3), Contraction::Mid) => {
            for a in 0..k {
                let iv = inv.row(a);
                let (ka, qa) = (s1[a] as usize, s3[a] as usize);
                let orow = out.row_mut(s2[a] as usize);
                for b in 0..k {
                    orow[s2[b] as usize] += w
                        * iv[b]
                        * l1.get(s1[b] as usize, ka)
                        * l3.get(s3[b] as usize, qa);
                }
            }
        }
        (KernelRef::Kron3(l1, l2, _), Contraction::A2) => {
            for a in 0..k {
                let iv = inv.row(a);
                let (ka, pa) = (s1[a] as usize, s2[a] as usize);
                let orow = out.row_mut(s3[a] as usize);
                for b in 0..k {
                    orow[s3[b] as usize] += w
                        * iv[b]
                        * l1.get(ka, s1[b] as usize)
                        * l2.get(pa, s2[b] as usize);
                }
            }
        }
        // Validated away in `contraction_dim`.
        (KernelRef::Kron2(..), Contraction::Mid) | (KernelRef::Full(_), _) => {
            unreachable!("contraction_dim rejects this combination")
        }
    }
}

/// Phase 1 of the dense-Θ path: inverses (and weighted logdets) for unique
/// subsets `[lo, lo + invs.len())` into the pool chunk.
#[allow(clippy::too_many_arguments)]
fn pool_range(
    kernel: KernelRef<'_>,
    stats: &CompressedTraining,
    lo: usize,
    sub: &mut Matrix,
    chol: &mut Matrix,
    tri: &mut Matrix,
    invs: &mut [Matrix],
    lds: &mut [f64],
) -> Result<()> {
    for (off, (inv, ld)) in invs.iter_mut().zip(lds.iter_mut()).enumerate() {
        let u = lo + off;
        let (s, e) = stats.range(u);
        let (s1, s2, s3) = stats.splits(s, e);
        gather_subset(kernel, &stats.items[s..e], s1, s2, s3, sub);
        cholesky::Cholesky::factor_into(sub, chol)?;
        let mut d = 0.0;
        for i in 0..(e - s) {
            d += chol.get(i, i).ln();
        }
        *ld = stats.weight(u) * 2.0 * d;
        cholesky::inverse_from_factor_into(chol, tri, inv);
    }
    Ok(())
}

/// Phase 2 of the dense-Θ path: scatter all pooled inverses into the Θ
/// rows `[lo, hi)` — each row receives its contributions in unique-subset
/// order, so the result is independent of how rows are banded.
fn scatter_rows(
    stats: &CompressedTraining,
    inv_pool: &[Matrix],
    lo: usize,
    hi: usize,
    band: &mut [f64],
    n: usize,
) {
    for u in 0..stats.unique() {
        let (s, e) = stats.range(u);
        let w = stats.weight(u);
        let items = &stats.items[s..e];
        for (a, &ta) in items.iter().enumerate() {
            if ta < lo || ta >= hi {
                continue;
            }
            let iv = inv_pool[u].row(a);
            let row = &mut band[(ta - lo) * n..(ta - lo + 1) * n];
            for (b, &tb) in items.iter().enumerate() {
                row[tb] += w * iv[b];
            }
        }
    }
}

/// Item index split for `L₁ ⊗ L₂`: `t = k·n2 + p ↦ (k, p)` (§2) — the one
/// shared definition behind the precomputed arena splits and the ad-hoc
/// minibatch splits.
#[inline]
fn split_item2(t: usize, n2: usize) -> (u32, u32) {
    ((t / n2) as u32, (t % n2) as u32)
}

/// Item index split for `L₁ ⊗ L₂ ⊗ L₃`: `t = (k·n2 + p)·n3 + q ↦ (k, p, q)`.
#[inline]
fn split_item3(t: usize, n2: usize, n3: usize) -> (u32, u32, u32) {
    let rest = t / n3;
    ((rest / n2) as u32, ((rest % n2) as u32), (t % n3) as u32)
}

/// Per-item index splits for an ad-hoc subset (the minibatch path).
fn split_indices(
    kernel: KernelRef<'_>,
    y: &[usize],
    b1: &mut Vec<u32>,
    b2: &mut Vec<u32>,
    b3: &mut Vec<u32>,
) {
    b1.clear();
    b2.clear();
    b3.clear();
    match kernel {
        KernelRef::Full(_) => {}
        KernelRef::Kron2(_, l2) => {
            let n2 = l2.rows();
            for &t in y {
                let (k, p) = split_item2(t, n2);
                b1.push(k);
                b2.push(p);
            }
        }
        KernelRef::Kron3(_, l2, l3) => {
            let (n2, n3) = (l2.rows(), l3.rows());
            for &t in y {
                let (k, p, q) = split_item3(t, n2, n3);
                b1.push(k);
                b2.push(p);
                b3.push(q);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    fn spd(n: usize, seed: u64) -> Matrix {
        let mut rng = Rng::new(seed);
        let mut m = rng.paper_init_kernel(n);
        m.scale_mut(1.0 / n as f64);
        m.add_diag_mut(0.3);
        m
    }

    #[test]
    fn dedup_collapses_duplicates_and_weights_sum() {
        let subsets = vec![
            vec![0, 3],
            vec![1],
            vec![0, 3],
            vec![],
            vec![0, 3],
            vec![2, 4, 5],
        ];
        let c =
            CompressedTraining::new(&subsets, KernelShape::Kron2 { n1: 2, n2: 3 }).unwrap();
        assert_eq!(c.unique(), 3);
        assert_eq!(c.n_total(), 6);
        assert_eq!(c.kappa(), 3);
        // Weights sum to (non-empty)/n.
        let total: f64 = (0..c.unique()).map(|u| c.weight(u)).sum();
        assert!((total - 5.0 / 6.0).abs() < 1e-15);
        // Dedup ratio counts multiplicity.
        assert!((c.dedup_ratio() - 5.0 / 3.0).abs() < 1e-15);
        // Sorted order: [0,3] (count 3), [1], [2,4,5].
        assert_eq!(c.subset(0), &[0, 3]);
        assert_eq!(c.subset(1), &[1]);
        assert_eq!(c.subset(2), &[2, 4, 5]);
        assert!((c.weight(0) - 0.5).abs() < 1e-15);
    }

    #[test]
    fn precomputed_splits_match_division() {
        let subsets = vec![vec![0, 5, 11], vec![7]];
        let c =
            CompressedTraining::new(&subsets, KernelShape::Kron3 { n1: 2, n2: 3, n3: 2 })
                .unwrap();
        for u in 0..c.unique() {
            let (lo, hi) = c.range(u);
            let (s1, s2, s3) = c.splits(lo, hi);
            for (i, &t) in c.subset(u).iter().enumerate() {
                assert_eq!(s3[i] as usize, t % 2);
                assert_eq!(s2[i] as usize, (t / 2) % 3);
                assert_eq!(s1[i] as usize, t / 6);
            }
        }
    }

    #[test]
    fn fingerprint_detects_changes() {
        let a = vec![vec![0, 1], vec![2]];
        let shape = KernelShape::Full { n: 4 };
        let c = CompressedTraining::new(&a, shape).unwrap();
        assert!(c.matches(&a));
        assert!(!c.matches(&[vec![0, 1], vec![3]]));
        assert!(!c.matches(&[vec![0, 1]]));
        // Order-sensitive (the fingerprint is a cheap identity check, not
        // a multiset hash — reordered data triggers a rebuild, which is
        // safe).
        assert!(!c.matches(&[vec![2], vec![0, 1]]));
    }

    #[test]
    fn rejects_bad_subsets_and_shape_mismatch() {
        let shape = KernelShape::Kron2 { n1: 2, n2: 2 };
        assert!(CompressedTraining::new(&[vec![1, 0]], shape).is_err());
        assert!(CompressedTraining::new(&[vec![0, 0]], shape).is_err());
        assert!(CompressedTraining::new(&[vec![4]], shape).is_err());
        let stats = CompressedTraining::new(&[vec![0, 1]], shape).unwrap();
        let l1 = spd(2, 1);
        let l2 = spd(3, 2);
        let mut eng = ThetaEngine::new();
        let mut out = Matrix::zeros(0, 0);
        // Kernel 2×3 vs stats built for 2×2.
        assert!(eng
            .contract(KernelRef::Kron2(&l1, &l2), &stats, Contraction::A1, &mut out)
            .is_err());
        // Mid needs three factors; Full has no block contraction.
        let l22 = spd(2, 3);
        assert!(eng
            .contract(KernelRef::Kron2(&l1, &l22), &stats, Contraction::Mid, &mut out)
            .is_err());
        let lf = spd(4, 4);
        let fstats =
            CompressedTraining::new(&[vec![0, 1]], KernelShape::Full { n: 4 }).unwrap();
        assert!(eng
            .contract(KernelRef::Full(&lf), &fstats, Contraction::A1, &mut out)
            .is_err());
    }

    #[test]
    fn stats_cache_rebuilds_only_on_change() {
        let shape = KernelShape::Full { n: 6 };
        let mut cache = StatsCache::default();
        let a = vec![vec![0, 2], vec![1]];
        let p1 = {
            let s = cache.get(&a, shape).unwrap();
            s as *const CompressedTraining
        };
        let p2 = {
            let s = cache.get(&a, shape).unwrap();
            s as *const CompressedTraining
        };
        assert_eq!(p1, p2, "unchanged data must not rebuild");
        let b = vec![vec![0, 2], vec![3]];
        let s = cache.get(&b, shape).unwrap();
        assert!(s.matches(&b));
        // Shape change also rebuilds.
        let s = cache.get(&b, KernelShape::Kron2 { n1: 2, n2: 3 }).unwrap();
        assert_eq!(s.shape(), KernelShape::Kron2 { n1: 2, n2: 3 });
    }

    #[test]
    fn logdet_lpi_matches_kernel_normalizer() {
        use crate::dpp::Kernel;
        use crate::linalg::eigen;
        let (l1, l2) = (spd(3, 11), spd(4, 12));
        let k = Kernel::Kron2(l1.clone(), l2.clone());
        let d1 = eigen::eigvals(&l1).unwrap();
        let d2 = eigen::eigvals(&l2).unwrap();
        let fast = logdet_lpi_kron2(&d1, &d2).unwrap();
        assert!((fast - k.logdet_l_plus_i().unwrap()).abs() < 1e-10);
        let l3 = spd(2, 13);
        let k3 = Kernel::Kron3(l1.clone(), l2.clone(), l3.clone());
        let d3 = eigen::eigvals(&l3).unwrap();
        let fast3 = logdet_lpi_kron3(&d1, &d2, &d3).unwrap();
        assert!((fast3 - k3.logdet_l_plus_i().unwrap()).abs() < 1e-10);
    }
}
