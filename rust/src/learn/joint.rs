//! Joint-Picard (§3.2, Algorithm 3, Appendix C).
//!
//! One full Picard step `L ← L + LΔL` followed by a projection back onto
//! Kronecker structure. Writing `L + LΔL = L(L⁻¹+Δ)L`, the paper instead
//! finds the best rank-1 rearrangement of `M = L⁻¹ + Δ` (Eq. 11) and maps
//! the factors back through the current sub-kernels:
//!
//! ```text
//! (U, σ, V) = top singular triple of R(M)
//! α = sgn(U₁₁)·√(σ‖L₂VL₂‖/‖L₁UL₁‖)
//! L₁ ← L₁ + a(α·L₁UL₁ − L₁),   L₂ ← L₂ + a(σ/α·L₂VL₂ − L₂)
//! ```
//!
//! (Algorithm 3 in the paper omits the `− L₂` in its last line; with
//! `a = 1` both reduce to `L₁' = αL₁UL₁`, `L₂' = (σ/α)L₂VL₂`, which is the
//! intended Eq.-8 projection — we implement the symmetric form.)
//!
//! The rearrangement `R(M)` is applied **without materializing `M`**:
//! `R(L₁⁻¹⊗L₂⁻¹)` is rank-1 (`vec(L₁⁻¹)vec(L₂⁻¹)ᵀ`), `R(Θ)` streams the
//! dense Θ, and `R((I+L)⁻¹)` factors through the sub-eigenbases as a
//! rank-N₁ product `Σ_k vec(P₁ₖP₁ₖᵀ)·vec(P₂D̃ₖP₂ᵀ)ᵀ` — giving the
//! `O(nκ³ + max(N₁,N₂)⁴)` cost quoted in §3.2. Theorem 3.2's ascent
//! guarantee does **not** apply here; the paper observes slower, noisier
//! convergence (Fig. 1), which our Fig-1 harness reproduces.

use crate::dpp::Kernel;
use crate::error::{Error, Result};
use crate::learn::stats::{KernelRef, KernelShape, StatsCache, ThetaEngine};
use crate::learn::traits::{Learner, TrainingSet};
use crate::linalg::eigen::SymEigen;
use crate::linalg::{cholesky, matmul, nkp, Matrix};

/// The Joint-Picard learner.
pub struct JointPicard {
    l1: Matrix,
    l2: Matrix,
    /// Step size `a ≥ 1` (Alg. 3).
    pub step_size: f64,
    /// Power-method iteration cap.
    pub power_iters: usize,
    /// Power-method relative tolerance.
    pub power_tol: f64,
    /// Θ assembly engine: `R(Θ)` streams a dense Θ, so this path keeps one
    /// — engine-built (dedup, pooled inverses, row-panel scatter) into a
    /// learner-held buffer instead of freshly allocated per step.
    engine: ThetaEngine,
    cache: StatsCache,
    theta: Matrix,
}

impl JointPicard {
    /// Start from PD sub-kernels.
    pub fn new(l1: Matrix, l2: Matrix, step_size: f64) -> Result<Self> {
        if !l1.is_square() || !l2.is_square() {
            return Err(Error::Shape("joint-picard: sub-kernels must be square".into()));
        }
        Ok(JointPicard {
            l1,
            l2,
            step_size,
            power_iters: 200,
            power_tol: 1e-11,
            engine: ThetaEngine::new(),
            cache: StatsCache::default(),
            theta: Matrix::zeros(0, 0),
        })
    }

    /// Borrow current sub-kernels.
    pub fn subkernels(&self) -> (&Matrix, &Matrix) {
        (&self.l1, &self.l2)
    }
}

/// The structured rearrangement operator `R(L⁻¹ + Θ − (I+L)⁻¹)`.
struct RearrangedGradient<'a> {
    theta: &'a Matrix,
    n1: usize,
    n2: usize,
    /// vec(L₁⁻¹), vec(L₂⁻¹) — rank-1 part.
    vl1inv: Vec<f64>,
    vl2inv: Vec<f64>,
    /// `u_mat` (N₁² × N₁): column k is vec(P₁ₖP₁ₖᵀ).
    u_mat: Matrix,
    /// `v_mat` (N₁ × N₂²): row k is vec(P₂·diag(1/(1+d₁ₖd₂))·P₂ᵀ).
    v_mat: Matrix,
}

impl<'a> RearrangedGradient<'a> {
    fn new(l1: &Matrix, l2: &Matrix, theta: &'a Matrix) -> Result<Self> {
        let n1 = l1.rows();
        let n2 = l2.rows();
        let e1 = SymEigen::new(l1)?;
        let e2 = SymEigen::new(l2)?;
        let l1inv = cholesky::inverse_pd(l1)?;
        let l2inv = cholesky::inverse_pd(l2)?;
        // u_mat: vec(P1[:,k] P1[:,k]ᵀ) per column — O(N1³).
        let mut u_mat = Matrix::zeros(n1 * n1, n1);
        for k in 0..n1 {
            let col = e1.vectors.col(k);
            for i in 0..n1 {
                for j in 0..n1 {
                    u_mat.set(i * n1 + j, k, col[i] * col[j]);
                }
            }
        }
        // v_mat: vec(P2 diag(1/(1+d1k·d2r)) P2ᵀ) per row — O(N1·N2³)
        // = O(max(N1,N2)⁴) for N1≈N2, the §3.2 cost.
        let mut v_mat = Matrix::zeros(n1, n2 * n2);
        for k in 0..n1 {
            let d1k = e1.values[k];
            let diag: Vec<f64> =
                e2.values.iter().map(|&d2r| 1.0 / (1.0 + d1k * d2r)).collect();
            let vk = crate::learn::krk::reconstruct_diag(&e2.vectors, &diag);
            v_mat.row_mut(k).copy_from_slice(vk.as_slice());
        }
        Ok(RearrangedGradient {
            theta,
            n1,
            n2,
            vl1inv: l1inv.into_vec(),
            vl2inv: l2inv.into_vec(),
            u_mat,
            v_mat,
        })
    }

    /// `y = R(M)·x`, `x ∈ R^{N₂²}`, `y ∈ R^{N₁²}` (caller-held output and
    /// mid buffers: the power loop allocates nothing).
    fn apply_into(&self, x: &[f64], y: &mut Vec<f64>, mid: &mut Vec<f64>, mid2: &mut Vec<f64>) {
        // Θ part.
        nkp::r_apply_into(self.theta, self.n1, self.n2, x, y);
        // + vec(L1⁻¹)·(vec(L2⁻¹)ᵀ x)
        let dot2: f64 = self.vl2inv.iter().zip(x).map(|(a, b)| a * b).sum();
        for (yi, li) in y.iter_mut().zip(&self.vl1inv) {
            *yi += li * dot2;
        }
        // − u_mat·(v_mat·x)
        mid.clear();
        mid.resize(self.n1, 0.0);
        matmul::matvec_into(mid, self.v_mat.view(), x);
        mid2.clear();
        mid2.resize(self.n1 * self.n1, 0.0);
        matmul::matvec_into(mid2, self.u_mat.view(), mid);
        for (yi, c) in y.iter_mut().zip(mid2.iter()) {
            *yi -= c;
        }
    }

    /// `x = R(M)ᵀ·y`, `y ∈ R^{N₁²}`, `x ∈ R^{N₂²}` (caller-held buffers;
    /// the transposed matvecs are free transpose views).
    fn apply_t_into(&self, y: &[f64], x: &mut Vec<f64>, mid: &mut Vec<f64>, mid2: &mut Vec<f64>) {
        nkp::rt_apply_into(self.theta, self.n1, self.n2, y, x);
        let dot1: f64 = self.vl1inv.iter().zip(y).map(|(a, b)| a * b).sum();
        for (xi, li) in x.iter_mut().zip(&self.vl2inv) {
            *xi += li * dot1;
        }
        mid.clear();
        mid.resize(self.n1, 0.0);
        matmul::matvec_into(mid, self.u_mat.view().t(), y);
        mid2.clear();
        mid2.resize(self.n2 * self.n2, 0.0);
        matmul::matvec_into(mid2, self.v_mat.view().t(), mid);
        for (xi, c) in x.iter_mut().zip(mid2.iter()) {
            *xi -= c;
        }
    }

    /// Top singular triple via power iteration on `RᵀR` (all iterate and
    /// intermediate buffers reused across iterations).
    fn top_singular(&self, iters: usize, tol: f64) -> Result<(Matrix, Matrix, f64)> {
        let mut v: Vec<f64> = vec![0.0; self.n2 * self.n2];
        // Deterministic PD-aligned start: identity.
        for r in 0..self.n2 {
            v[r * self.n2 + r] = 1.0;
        }
        normalize(&mut v)?;
        let mut u = vec![0.0; self.n1 * self.n1];
        let (mut mid, mut mid2) = (Vec::new(), Vec::new());
        let mut sigma = 0.0;
        let mut prev = 0.0;
        for _ in 0..iters {
            self.apply_into(&v, &mut u, &mut mid, &mut mid2);
            normalize(&mut u)?;
            self.apply_t_into(&u, &mut v, &mut mid, &mut mid2);
            sigma = normalize(&mut v)?;
            if (sigma - prev).abs() <= tol * sigma.abs().max(1e-300) {
                break;
            }
            prev = sigma;
        }
        Ok((
            Matrix::from_vec(self.n1, self.n1, u)?,
            Matrix::from_vec(self.n2, self.n2, v)?,
            sigma,
        ))
    }
}

fn normalize(x: &mut [f64]) -> Result<f64> {
    let n: f64 = x.iter().map(|v| v * v).sum::<f64>().sqrt();
    if n < 1e-300 || !n.is_finite() {
        return Err(Error::Numerical("joint-picard: degenerate power iterate".into()));
    }
    for v in x.iter_mut() {
        *v /= n;
    }
    Ok(n)
}

impl Learner for JointPicard {
    fn name(&self) -> &'static str {
        "joint-picard"
    }

    fn step(&mut self, data: &TrainingSet) -> Result<()> {
        let (n1, n2) = (self.l1.rows(), self.l2.rows());
        {
            let stats = self.cache.get(&data.subsets, KernelShape::Kron2 { n1, n2 })?;
            self.engine.theta_dense_into(
                KernelRef::Kron2(&self.l1, &self.l2),
                stats,
                &mut self.theta,
            )?;
        }
        let op = RearrangedGradient::new(&self.l1, &self.l2, &self.theta)?;
        let (mut u, mut v, sigma) = op.top_singular(self.power_iters, self.power_tol)?;
        // Thm. C.1: U, V are both PD or both ND; fix the sign from U₁₁.
        if u.get(0, 0) < 0.0 {
            u.scale_mut(-1.0);
            v.scale_mut(-1.0);
        }
        u.symmetrize_mut();
        v.symmetrize_mut();
        let l1ul1 = matmul::sandwich(&self.l1, &u, &self.l1)?;
        let l2vl2 = matmul::sandwich(&self.l2, &v, &self.l2)?;
        let alpha =
            (sigma * l2vl2.fro_norm() / l1ul1.fro_norm().max(1e-300)).sqrt();
        // L1 ← L1 + a(α·L1UL1 − L1); L2 ← L2 + a(σ/α·L2VL2 − L2).
        let a = self.step_size;
        let mut new_l1 = self.l1.scaled(1.0 - a);
        new_l1.axpy(a * alpha, &l1ul1)?;
        let mut new_l2 = self.l2.scaled(1.0 - a);
        new_l2.axpy(a * sigma / alpha, &l2vl2)?;
        new_l1.symmetrize_mut();
        new_l2.symmetrize_mut();
        self.l1 = new_l1;
        self.l2 = new_l2;
        Ok(())
    }

    fn kernel(&self) -> Kernel {
        Kernel::Kron2(self.l1.clone(), self.l2.clone())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dpp::likelihood::{log_likelihood, theta_dense};
    use crate::dpp::Sampler;
    use crate::rng::Rng;

    fn sub_kernel(n: usize, rng: &mut Rng) -> Matrix {
        let mut l = rng.paper_init_kernel(n);
        l.scale_mut(1.5 / n as f64);
        l.add_diag_mut(0.3);
        l
    }

    fn setup(n1: usize, n2: usize, count: usize, seed: u64) -> (TrainingSet, JointPicard) {
        let mut rng = Rng::new(seed);
        let truth = Kernel::Kron2(sub_kernel(n1, &mut rng), sub_kernel(n2, &mut rng));
        let sampler = Sampler::new(&truth).unwrap();
        let subsets: Vec<Vec<usize>> =
            (0..count).map(|_| sampler.sample(&mut rng)).collect();
        let data = TrainingSet::new(n1 * n2, subsets).unwrap();
        let learner =
            JointPicard::new(sub_kernel(n1, &mut rng), sub_kernel(n2, &mut rng), 1.0).unwrap();
        (data, learner)
    }

    #[test]
    fn structured_rearrangement_matches_dense() {
        // R(M)·x via the structured operator must equal the dense
        // rearrangement of M = L⁻¹ + Θ − (I+L)⁻¹ applied via NKP's apply.
        let (data, learner) = setup(3, 4, 20, 31);
        let kernel = learner.kernel();
        let theta = theta_dense(&kernel, &data.subsets).unwrap();
        let op = RearrangedGradient::new(&learner.l1, &learner.l2, &theta).unwrap();
        // Dense M.
        let l = kernel.to_dense();
        let linv = cholesky::inverse_pd(&l).unwrap();
        let mut lpi = l.clone();
        lpi.add_diag_mut(1.0);
        let lpi_inv = cholesky::inverse_pd(&lpi).unwrap();
        let mut m = linv;
        m += &theta;
        m -= &lpi_inv;
        let x: Vec<f64> = (0..16).map(|i| ((i * 7 % 11) as f64) - 5.0).collect();
        let (mut fast, mut mid, mut mid2) = (Vec::new(), Vec::new(), Vec::new());
        op.apply_into(&x, &mut fast, &mut mid, &mut mid2);
        let slow = nkp::r_apply(&m, 3, 4, &x);
        for (p, q) in fast.iter().zip(&slow) {
            assert!((p - q).abs() < 1e-9, "{p} vs {q}");
        }
        let y: Vec<f64> = (0..9).map(|i| ((i * 5 % 7) as f64) - 3.0).collect();
        let mut fast_t = Vec::new();
        op.apply_t_into(&y, &mut fast_t, &mut mid, &mut mid2);
        let slow_t = nkp::rt_apply(&m, 3, 4, &y);
        for (p, q) in fast_t.iter().zip(&slow_t) {
            assert!((p - q).abs() < 1e-9, "{p} vs {q}");
        }
    }

    #[test]
    fn iterates_stay_pd() {
        // Thm. C.1 + sign fixing: PD preserved.
        let (data, mut learner) = setup(3, 3, 30, 33);
        for _ in 0..10 {
            learner.step(&data).unwrap();
            assert!(cholesky::is_pd(&learner.l1), "L1 lost PD");
            assert!(cholesky::is_pd(&learner.l2), "L2 lost PD");
        }
    }

    #[test]
    fn norms_balanced_after_step() {
        // Eq. 8 side constraint: ‖L₁‖ = ‖L₂‖ after an a=1 step.
        let (data, mut learner) = setup(3, 4, 25, 35);
        learner.step(&data).unwrap();
        let (l1, l2) = learner.subkernels();
        assert!(
            (l1.fro_norm() - l2.fro_norm()).abs() / l1.fro_norm() < 1e-8,
            "{} vs {}",
            l1.fro_norm(),
            l2.fro_norm()
        );
    }

    #[test]
    fn improves_likelihood_overall() {
        let (data, mut learner) = setup(3, 3, 40, 37);
        let ll0 = log_likelihood(&learner.kernel(), &data.subsets).unwrap();
        let result = learner.run(&data, 15, 0.0).unwrap();
        assert!(result.final_ll() > ll0, "{} -> {}", ll0, result.final_ll());
    }
}
