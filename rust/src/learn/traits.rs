//! Shared learner interfaces: training sets, per-iteration records, and the
//! [`Learner`] trait every algorithm (Picard, KRK-Picard, Joint-Picard, EM)
//! implements so the figure harness and the coordinator's learning jobs can
//! drive them interchangeably.

use crate::dpp::likelihood;
use crate::dpp::{Kernel, KernelDelta};
use crate::error::{Error, Result};
use std::time::{Duration, Instant};

/// A training corpus: `n` observed subsets over a ground set of size
/// `ground_size`.
#[derive(Clone, Debug)]
pub struct TrainingSet {
    pub ground_size: usize,
    pub subsets: Vec<Vec<usize>>,
}

impl TrainingSet {
    /// Validate and build.
    pub fn new(ground_size: usize, subsets: Vec<Vec<usize>>) -> Result<Self> {
        for (k, y) in subsets.iter().enumerate() {
            for &i in y {
                if i >= ground_size {
                    return Err(Error::Invalid(format!(
                        "training subset {k} references item {i} ≥ N={ground_size}"
                    )));
                }
            }
            if y.windows(2).any(|w| w[0] >= w[1]) {
                return Err(Error::Invalid(format!(
                    "training subset {k} is not sorted/unique"
                )));
            }
        }
        Ok(TrainingSet { ground_size, subsets })
    }

    /// Number of training subsets `n`.
    pub fn len(&self) -> usize {
        self.subsets.len()
    }

    pub fn is_empty(&self) -> bool {
        self.subsets.is_empty()
    }

    /// Size of the largest subset (the paper's `κ`).
    pub fn kappa(&self) -> usize {
        self.subsets.iter().map(|y| y.len()).max().unwrap_or(0)
    }

    /// Mean subset size.
    pub fn mean_size(&self) -> f64 {
        if self.subsets.is_empty() {
            return 0.0;
        }
        self.subsets.iter().map(|y| y.len()).sum::<usize>() as f64 / self.subsets.len() as f64
    }
}

/// Per-iteration progress record.
#[derive(Clone, Debug)]
pub struct IterRecord {
    /// 1-based iteration number (0 = initial state).
    pub iter: usize,
    /// Cumulative wall-clock since learning started.
    pub elapsed: Duration,
    /// Mean log-likelihood φ after this iteration.
    pub log_likelihood: f64,
}

/// Outcome of a learning run.
#[derive(Debug)]
pub struct LearnResult {
    /// Final kernel estimate.
    pub kernel: Kernel,
    /// Objective trace; `history[0]` is the initial likelihood.
    pub history: Vec<IterRecord>,
    /// True if the δ-threshold stopping rule fired (vs. iteration cap).
    pub converged: bool,
}

impl LearnResult {
    /// Final log-likelihood.
    pub fn final_ll(&self) -> f64 {
        self.history.last().map(|r| r.log_likelihood).unwrap_or(f64::NAN)
    }

    /// Log-likelihood increase achieved by the first iteration — the
    /// "NLL increase (1st iter.)" row of the paper's Table 2.
    pub fn first_iter_gain(&self) -> f64 {
        if self.history.len() < 2 {
            return 0.0;
        }
        self.history[1].log_likelihood - self.history[0].log_likelihood
    }

    /// Mean seconds per iteration (excluding the initial evaluation).
    pub fn mean_iter_secs(&self) -> f64 {
        if self.history.len() < 2 {
            return 0.0;
        }
        let total = self.history.last().unwrap().elapsed.as_secs_f64();
        total / (self.history.len() - 1) as f64
    }
}

/// A DPP kernel learner.
pub trait Learner {
    /// Human-readable name (appears in figure legends / bench rows).
    fn name(&self) -> &'static str;

    /// One optimization step in place; returns nothing — progress is
    /// observed via `kernel()` and the driver's likelihood evaluation.
    fn step(&mut self, data: &TrainingSet) -> Result<()>;

    /// One optimization step that also **describes its own effect** as a
    /// sequence of [`KernelDelta`]s, so a serving tenant can absorb the
    /// refresh incrementally
    /// ([`crate::coordinator::KernelRegistry::publish_delta`]) instead of
    /// re-eigendecomposing the whole republished kernel.
    ///
    /// Contract: after this call, applying the returned deltas (in order)
    /// to the kernel the learner held *before* the call must reproduce
    /// `self.kernel()` **exactly** — learners that compress their step
    /// into low-rank deltas must write the compressed step back into
    /// their own iterate so learner and tenant stay in lockstep.
    ///
    /// `Ok(None)` means "no delta form available" (the default): the
    /// caller falls back to a full publish of `self.kernel()`.
    fn step_delta(&mut self, data: &TrainingSet) -> Result<Option<Vec<KernelDelta>>> {
        self.step(data)?;
        Ok(None)
    }

    /// Current kernel estimate (cloned).
    fn kernel(&self) -> Kernel;

    /// Mean log-likelihood φ (Eq. 3) of the current iterate — what
    /// [`Learner::run`] records per iteration. The default evaluates the
    /// dense path; learners holding compressed statistics override it with
    /// the fused engine sweep (deduplicated, parallel, allocation-free —
    /// same value up to floating-point association).
    fn objective(&mut self, data: &TrainingSet) -> Result<f64> {
        likelihood::log_likelihood(&self.kernel(), &data.subsets)
    }

    /// Run `max_iters` steps with likelihood tracking; stops early when
    /// `|φ_{k+1} − φ_k| < tol` (if `tol > 0`). The likelihood evaluation
    /// is *not* counted in `elapsed` (matching how the paper reports
    /// per-iteration runtimes).
    fn run(&mut self, data: &TrainingSet, max_iters: usize, tol: f64) -> Result<LearnResult> {
        let mut history = Vec::with_capacity(max_iters + 1);
        let ll0 = self.objective(data)?;
        history.push(IterRecord { iter: 0, elapsed: Duration::ZERO, log_likelihood: ll0 });
        let mut elapsed = Duration::ZERO;
        let mut converged = false;
        for it in 1..=max_iters {
            let t = Instant::now();
            self.step(data)?;
            elapsed += t.elapsed();
            let ll = self.objective(data)?;
            history.push(IterRecord { iter: it, elapsed, log_likelihood: ll });
            let prev = history[history.len() - 2].log_likelihood;
            if tol > 0.0 && (ll - prev).abs() < tol {
                converged = true;
                break;
            }
        }
        Ok(LearnResult { kernel: self.kernel(), history, converged })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn training_set_validation() {
        assert!(TrainingSet::new(5, vec![vec![0, 4]]).is_ok());
        assert!(TrainingSet::new(5, vec![vec![0, 5]]).is_err());
        assert!(TrainingSet::new(5, vec![vec![3, 1]]).is_err());
        assert!(TrainingSet::new(5, vec![vec![2, 2]]).is_err());
    }

    #[test]
    fn kappa_and_mean() {
        let t = TrainingSet::new(10, vec![vec![0], vec![1, 2, 3], vec![4, 5]]).unwrap();
        assert_eq!(t.kappa(), 3);
        assert!((t.mean_size() - 2.0).abs() < 1e-12);
        assert_eq!(t.len(), 3);
    }

    #[test]
    fn result_accessors() {
        let r = LearnResult {
            kernel: Kernel::Full(crate::linalg::Matrix::identity(2)),
            history: vec![
                IterRecord { iter: 0, elapsed: Duration::ZERO, log_likelihood: -10.0 },
                IterRecord {
                    iter: 1,
                    elapsed: Duration::from_secs(2),
                    log_likelihood: -8.0,
                },
                IterRecord {
                    iter: 2,
                    elapsed: Duration::from_secs(4),
                    log_likelihood: -7.5,
                },
            ],
            converged: false,
        };
        assert_eq!(r.final_ll(), -7.5);
        assert_eq!(r.first_iter_gain(), 2.0);
        assert!((r.mean_iter_secs() - 2.0).abs() < 1e-12);
    }
}
