//! The full Picard iteration of Mariet & Sra, ICML 2015 (ref. [25]) — the
//! paper's primary baseline.
//!
//! Iterates `L ← L + a·LΔL` with `Δ = Θ − (I+L)⁻¹` (Eqs. 4–5). Each step
//! costs `O(nκ³ + N³)`: `Θ` assembly plus the dense inverse and the two
//! `N×N` products. The full kernel genuinely needs the dense Θ (there is
//! no sub-factor structure to contract into), so this path routes Θ
//! assembly through [`crate::learn::stats::ThetaEngine::theta_dense_into`]:
//! duplicate subsets factor once, the inverses pool in reused buffers, and
//! the scatter runs over per-worker Θ row panels instead of serially — the
//! Θ buffer itself persists across iterations. With `a = 1` the
//! log-likelihood is guaranteed non-decreasing ([25, Thm 2.2]); `a > 1`
//! (the paper uses 1.3) trades the guarantee for speed.

use crate::dpp::Kernel;
use crate::error::{Error, Result};
use crate::learn::stats::{KernelRef, KernelShape, StatsCache, ThetaEngine};
use crate::learn::traits::{Learner, TrainingSet};
use crate::linalg::{cholesky, matmul, Matrix};

/// Full-kernel Picard learner.
pub struct Picard {
    l: Matrix,
    /// Step size `a` (1.0 = guaranteed ascent).
    pub step_size: f64,
    /// Fall back to the a = 1 step when an aggressive step leaves the PD
    /// cone (on by default; the step-size ablation disables it to measure
    /// the raw admissible range).
    pub safeguard: bool,
    /// Step candidate / rollback buffer (no per-step kernel clone).
    candidate: Matrix,
    /// PD-check factor buffer.
    cholwork: Matrix,
    /// Θ assembly engine (pooled subset inverses, row-panel scatter).
    engine: ThetaEngine,
    /// Compressed training statistics (dedup weights).
    cache: StatsCache,
    /// Θ buffer, reused across iterations (holds Δ after the subtraction).
    theta: Matrix,
}

impl Picard {
    /// Start from an initial PD kernel.
    pub fn new(l0: Matrix, step_size: f64) -> Result<Self> {
        if !l0.is_square() {
            return Err(Error::Shape("picard: kernel must be square".into()));
        }
        Ok(Picard {
            l: l0,
            step_size,
            safeguard: true,
            candidate: Matrix::zeros(0, 0),
            cholwork: Matrix::zeros(0, 0),
            engine: ThetaEngine::new(),
            cache: StatsCache::default(),
            theta: Matrix::zeros(0, 0),
        })
    }

    /// Borrow the current kernel matrix.
    pub fn kernel_matrix(&self) -> &Matrix {
        &self.l
    }
}

impl Learner for Picard {
    fn name(&self) -> &'static str {
        "picard"
    }

    fn step(&mut self, data: &TrainingSet) -> Result<()> {
        // Θ = (1/n) Σ U_i L_{Y_i}^{-1} U_iᵀ — O(nκ³), engine-assembled
        // (dedup weights, pooled inverses, row-panel parallel scatter).
        let n = self.l.rows();
        {
            let stats = self.cache.get(&data.subsets, KernelShape::Full { n })?;
            self.engine.theta_dense_into(KernelRef::Full(&self.l), stats, &mut self.theta)?;
        }
        // Δ = Θ − (I+L)^{-1}, in the Θ buffer.
        let mut l_plus_i = self.l.clone();
        l_plus_i.add_diag_mut(1.0);
        let inv = cholesky::inverse_pd(&l_plus_i)?;
        self.theta -= &inv;
        // L ← L + a·LΔL. For a > 1 PD is no longer guaranteed (§3.1.1 /
        // [25]); safeguard by falling back to the a = 1 step, which is.
        // Candidate + rollback live in learner-held buffers (no clones).
        let ldl = matmul::sandwich(&self.l, &self.theta, &self.l)?;
        crate::learn::krk::apply_step_into(
            &mut self.l,
            &ldl,
            self.step_size,
            1.0,
            self.safeguard,
            &mut self.candidate,
            &mut self.cholwork,
        );
        Ok(())
    }

    fn kernel(&self) -> Kernel {
        Kernel::Full(self.l.clone())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dpp::likelihood::log_likelihood;
    use crate::dpp::Sampler;
    use crate::rng::Rng;

    fn ground_truth_and_data(n: usize, count: usize, seed: u64) -> (Kernel, TrainingSet) {
        let mut rng = Rng::new(seed);
        let mut l = rng.paper_init_kernel(n);
        l.scale_mut(2.0 / n as f64);
        l.add_diag_mut(0.5);
        let kernel = Kernel::Full(l);
        let sampler = Sampler::new(&kernel).unwrap();
        let subsets: Vec<Vec<usize>> =
            (0..count).map(|_| sampler.sample(&mut rng)).collect();
        let data = TrainingSet::new(n, subsets).unwrap();
        (kernel, data)
    }

    #[test]
    fn monotonic_ascent_with_unit_step() {
        let (_, data) = ground_truth_and_data(12, 40, 1);
        let mut rng = Rng::new(2);
        let mut init = rng.paper_init_kernel(12);
        init.scale_mut(1.0 / 12.0);
        init.add_diag_mut(0.4);
        let mut learner = Picard::new(init, 1.0).unwrap();
        let result = learner.run(&data, 15, 0.0).unwrap();
        for w in result.history.windows(2) {
            assert!(
                w[1].log_likelihood >= w[0].log_likelihood - 1e-9,
                "descent at iter {}: {} -> {}",
                w[1].iter,
                w[0].log_likelihood,
                w[1].log_likelihood
            );
        }
    }

    #[test]
    fn iterates_remain_pd() {
        let (_, data) = ground_truth_and_data(10, 30, 3);
        let mut rng = Rng::new(4);
        let mut init = rng.paper_init_kernel(10);
        init.scale_mut(1.0 / 10.0);
        init.add_diag_mut(0.4);
        let mut learner = Picard::new(init, 1.0).unwrap();
        for _ in 0..10 {
            learner.step(&data).unwrap();
            assert!(cholesky::is_pd(learner.kernel_matrix()));
        }
    }

    #[test]
    fn improves_over_initialization() {
        let (_, data) = ground_truth_and_data(12, 60, 5);
        let mut rng = Rng::new(6);
        let mut init = rng.paper_init_kernel(12);
        init.scale_mut(1.0 / 12.0);
        init.add_diag_mut(0.4);
        let ll0 = log_likelihood(&Kernel::Full(init.clone()), &data.subsets).unwrap();
        let mut learner = Picard::new(init, 1.0).unwrap();
        let result = learner.run(&data, 20, 0.0).unwrap();
        assert!(
            result.final_ll() > ll0 + 0.1,
            "no meaningful improvement: {} -> {}",
            ll0,
            result.final_ll()
        );
    }

    #[test]
    fn convergence_threshold_stops_early() {
        let (_, data) = ground_truth_and_data(8, 30, 7);
        let mut rng = Rng::new(8);
        let mut init = rng.paper_init_kernel(8);
        init.scale_mut(1.0 / 8.0);
        init.add_diag_mut(0.4);
        let mut learner = Picard::new(init, 1.0).unwrap();
        let result = learner.run(&data, 500, 1e-4).unwrap();
        assert!(result.converged, "should hit δ threshold before 500 iters");
        assert!(result.history.len() < 501);
    }
}
