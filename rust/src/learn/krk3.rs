//! Three-factor KRK-Picard — the paper's multiblock generalization
//! (§3.1.1): learning `L = L₁ ⊗ L₂ ⊗ L₃` by block-coordinate updates
//!
//! `(L_k)_{ij} ← (L_k)_{ij} + a·N_k/N ·
//!     Tr[(L₁⊗…⊗E_{ij}⊗…⊗L₃)(LΔL)]`.
//!
//! Implementation strategy: the outer factors are handled by *grouping* —
//! updating `L₁` treats `B = L₂⊗L₃` as a single (dense) second factor and
//! reuses the m = 2 machinery verbatim (block-trace contraction +
//! sub-spectrum `B`-matrix); symmetrically for `L₃` with `A = L₁⊗L₂`. The
//! *middle* factor needs a genuinely new contraction,
//! [`kron::mixed_weighted_trace`]:
//!
//! Note: the paper's §3.1.1 multiblock display writes the non-updated
//! slots as `L_l`; consistency with Prop. 3.1 (whose m = 2 trace carries
//! `I ⊗ S₂`, `S₂ = L₂⁻¹`) requires the **inverses** `L_l⁻¹` there — the
//! as-printed form does not reduce to Eq. 7 at m = 2. We implement the
//! consistent form and verify each factor update against the dense
//! definition `Tr[(L₁⁻¹⊗E_{ij}⊗L₃⁻¹)(LΔL)]` in the tests below.
//!
//! - Θ-half: `Tr[(L₁⁻¹⊗E_{pq}⊗L₃⁻¹)·LΘL] = (L₂·Hᵀ·L₂)[p,q]` with
//!   `H[j',j] = Σ W₁[i,i']W₃[r,r']·Θ[(i',j',r'),(i,j,r)]`, `W₁ = L₁`,
//!   `W₃ = L₃` (cyclic trace + mixed-product identities);
//! - `(I+L)⁻¹`-half: in the joint eigenbasis it collapses to
//!   `P₂·diag(W)·P₂ᵀ` with
//!   `W[m] = Σ_{k,s} d₁ₖ·d₂ₘ²·d₃ₛ/(1+d₁ₖd₂ₘd₃ₛ)` — see `middle_b_diag`.
//!
//! Grouped updates cost `O(N² + (N₂N₃)³)`-ish; practical when the two
//! grouped factors stay moderate, which is exactly the m = 3 regime the
//! paper targets (§4: three factors make sampling linear in N).

use crate::dpp::likelihood::theta_dense;
use crate::dpp::Kernel;
use crate::error::{Error, Result};
use crate::learn::krk::{apply_safeguarded, b2_matrix, l1_b_l1, reconstruct_diag};
use crate::learn::traits::{Learner, TrainingSet};
use crate::linalg::eigen::SymEigen;
use crate::linalg::{kron, matmul, Matrix};

/// KRK-Picard for `L = L₁ ⊗ L₂ ⊗ L₃`.
pub struct Krk3Picard {
    l1: Matrix,
    l2: Matrix,
    l3: Matrix,
    /// Step size `a`.
    pub step_size: f64,
}

impl Krk3Picard {
    pub fn new(l1: Matrix, l2: Matrix, l3: Matrix, step_size: f64) -> Result<Self> {
        if !l1.is_square() || !l2.is_square() || !l3.is_square() {
            return Err(Error::Shape("krk3: sub-kernels must be square".into()));
        }
        Ok(Krk3Picard { l1, l2, l3, step_size })
    }

    pub fn dims(&self) -> (usize, usize, usize) {
        (self.l1.rows(), self.l2.rows(), self.l3.rows())
    }

    pub fn subkernels(&self) -> (&Matrix, &Matrix, &Matrix) {
        (&self.l1, &self.l2, &self.l3)
    }

    /// Update L₁ by grouping `B = L₂⊗L₃` (m=2 machinery, Prop. 3.1).
    fn update_l1(&mut self, theta: &Matrix) -> Result<()> {
        let (n1, n2, n3) = self.dims();
        let b = kron::kron(&self.l2, &self.l3);
        let a1 = kron::block_trace(theta, &b, n1, n2 * n3)?;
        let l1a1l1 = matmul::sandwich(&self.l1, &a1, &self.l1)?;
        let l1bl1 = l1_b_l1(&self.l1, &b)?;
        let mut x = l1a1l1;
        x -= &l1bl1;
        apply_safeguarded(
            &mut self.l1,
            &x,
            self.step_size / (n2 * n3) as f64,
            1.0 / (n2 * n3) as f64,
        );
        Ok(())
    }

    /// Update L₃ by grouping `A = L₁⊗L₂`.
    fn update_l3(&mut self, theta: &Matrix) -> Result<()> {
        let (n1, n2, n3) = self.dims();
        let a = kron::kron(&self.l1, &self.l2);
        let a2 = kron::weighted_block_sum(theta, &a, n1 * n2, n3)?;
        let l3a2l3 = matmul::sandwich(&self.l3, &a2, &self.l3)?;
        let b3 = b2_matrix(&a, &self.l3)?;
        let mut x = l3a2l3;
        x -= &b3;
        apply_safeguarded(
            &mut self.l3,
            &x,
            self.step_size / (n1 * n2) as f64,
            1.0 / (n1 * n2) as f64,
        );
        Ok(())
    }

    /// Update the middle factor L₂ via the mixed contraction.
    fn update_l2(&mut self, theta: &Matrix) -> Result<()> {
        let (n1, n2, n3) = self.dims();
        // Θ-half: H with weights L₁, L₃ (from L·(L₁⁻¹⊗E⊗L₃⁻¹)·L =
        // L₁⊗L₂EL₂⊗L₃ under the cyclic trace), then L₂·Hᵀ·L₂.
        let h = kron::mixed_weighted_trace(theta, &self.l1, &self.l3, n1, n2, n3)?;
        let theta_part = matmul::sandwich(&self.l2, &h.transpose(), &self.l2)?;
        // (I+L)⁻¹-half: P₂ diag(W) P₂ᵀ in the middle eigenbasis.
        let e1 = SymEigen::new(&self.l1)?;
        let e2 = SymEigen::new(&self.l2)?;
        let e3 = SymEigen::new(&self.l3)?;
        let wdiag = middle_b_diag(&e1.values, &e2.values, &e3.values);
        let b_part = reconstruct_diag(&e2.vectors, &wdiag);
        let mut x = theta_part;
        x -= &b_part;
        apply_safeguarded(
            &mut self.l2,
            &x,
            self.step_size / (n1 * n3) as f64,
            1.0 / (n1 * n3) as f64,
        );
        Ok(())
    }
}

/// Middle-factor `(I+L)⁻¹` diagonal:
/// `W[m] = Σ_{k,s} d₁ₖ·d₂ₘ²·d₃ₛ/(1 + d₁ₖd₂ₘd₃ₛ)`
/// — from `Tr[(L₁⁻¹⊗E_{pq}⊗L₃⁻¹)·L(I+L)⁻¹L]` in the joint eigenbasis:
/// `Pᵀ(L₁⁻¹⊗E⊗L₃⁻¹)P = D₁⁻¹ ⊗ (P₂ᵀEP₂) ⊗ D₃⁻¹`, and `L(I+L)⁻¹L` has
/// eigenvalues `λ²/(1+λ)` with `λ = d₁ₖd₂ₘd₃ₛ`, so the trace collects
/// `λ²/((1+λ)·d₁ₖd₃ₛ) = d₁ₖd₂ₘ²d₃ₛ/(1+λ)` per `(k,s)` pair.
fn middle_b_diag(d1: &[f64], d2: &[f64], d3: &[f64]) -> Vec<f64> {
    d2.iter()
        .map(|&dm| {
            let mut acc = 0.0;
            for &dk in d1 {
                for &ds in d3 {
                    let lam = dk * dm * ds;
                    acc += dk * dm * dm * ds / (1.0 + lam);
                }
            }
            acc
        })
        .collect()
}

impl Learner for Krk3Picard {
    fn name(&self) -> &'static str {
        "krk3-picard"
    }

    fn step(&mut self, data: &TrainingSet) -> Result<()> {
        let theta = theta_dense(&self.kernel(), &data.subsets)?;
        self.update_l1(&theta)?;
        let theta = theta_dense(&self.kernel(), &data.subsets)?;
        self.update_l2(&theta)?;
        let theta = theta_dense(&self.kernel(), &data.subsets)?;
        self.update_l3(&theta)?;
        Ok(())
    }

    fn kernel(&self) -> Kernel {
        Kernel::Kron3(self.l1.clone(), self.l2.clone(), self.l3.clone())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dpp::Sampler;
    use crate::linalg::cholesky;
    use crate::rng::Rng;

    fn sub_kernel(n: usize, rng: &mut Rng) -> Matrix {
        let mut l = rng.paper_init_kernel(n);
        l.scale_mut(1.2 / n as f64);
        l.add_diag_mut(0.35);
        l
    }

    fn setup(
        n1: usize,
        n2: usize,
        n3: usize,
        count: usize,
        seed: u64,
    ) -> (TrainingSet, Krk3Picard) {
        let mut rng = Rng::new(seed);
        let truth = Kernel::Kron3(
            sub_kernel(n1, &mut rng),
            sub_kernel(n2, &mut rng),
            sub_kernel(n3, &mut rng),
        );
        let sampler = Sampler::new(&truth).unwrap();
        let subsets: Vec<Vec<usize>> =
            (0..count).map(|_| sampler.sample(&mut rng)).collect();
        let data = TrainingSet::new(n1 * n2 * n3, subsets).unwrap();
        let learner = Krk3Picard::new(
            sub_kernel(n1, &mut rng),
            sub_kernel(n2, &mut rng),
            sub_kernel(n3, &mut rng),
            1.0,
        )
        .unwrap();
        (data, learner)
    }

    /// Dense reference for one factor update via the (Prop.-3.1-consistent)
    /// multiblock formula: `X_{ij} = Tr[(…L⁻¹…⊗E_{ij}⊗…L⁻¹…)(LΔL)]`
    /// computed literally.
    fn dense_factor_update(
        l1: &Matrix,
        l2: &Matrix,
        l3: &Matrix,
        data: &TrainingSet,
        factor: usize,
    ) -> Matrix {
        let kernel = Kernel::Kron3(l1.clone(), l2.clone(), l3.clone());
        let l = kernel.to_dense();
        let theta = theta_dense(&kernel, &data.subsets).unwrap();
        let mut lpi = l.clone();
        lpi.add_diag_mut(1.0);
        let inv = cholesky::inverse_pd(&lpi).unwrap();
        let mut delta = theta;
        delta -= &inv;
        let ldl = matmul::sandwich(&l, &delta, &l).unwrap();
        let nk = [l1.rows(), l2.rows(), l3.rows()][factor];
        let mut x = Matrix::zeros(nk, nk);
        for i in 0..nk {
            for j in 0..nk {
                let mut e = Matrix::zeros(nk, nk);
                e.set(i, j, 1.0);
                let inv1 = cholesky::inverse_pd(l1).unwrap();
                let inv2 = cholesky::inverse_pd(l2).unwrap();
                let inv3 = cholesky::inverse_pd(l3).unwrap();
                let probe = match factor {
                    0 => kron::kron3(&e, &inv2, &inv3),
                    1 => kron::kron3(&inv1, &e, &inv3),
                    _ => kron::kron3(&inv1, &inv2, &e),
                };
                // Tr[probe · LΔL]
                let mut tr = 0.0;
                let n = probe.rows();
                for r in 0..n {
                    tr += matmul::dot(probe.row(r), {
                        // column r of ldl == row r (symmetric? LΔL is
                        // symmetric since L, Δ are) — use row.
                        ldl.row(r)
                    });
                }
                x.set(i, j, tr);
            }
        }
        x
    }

    #[test]
    fn grouped_l1_update_matches_dense_definition() {
        let (data, learner) = setup(2, 3, 2, 15, 1);
        let (l1, l2, l3) = (learner.l1.clone(), learner.l2.clone(), learner.l3.clone());
        let x_ref = dense_factor_update(&l1, &l2, &l3, &data, 0);
        // Efficient path pieces:
        let theta = theta_dense(&learner.kernel(), &data.subsets).unwrap();
        let b = kron::kron(&l2, &l3);
        let a1 = kron::block_trace(&theta, &b, 2, 6).unwrap();
        let l1a1l1 = matmul::sandwich(&l1, &a1, &l1).unwrap();
        let l1bl1 = l1_b_l1(&l1, &b).unwrap();
        let mut x = l1a1l1;
        x -= &l1bl1;
        assert!(x.rel_diff(&x_ref) < 1e-8, "L1 update mismatch: {}", x.rel_diff(&x_ref));
    }

    #[test]
    fn middle_l2_update_matches_dense_definition() {
        let (data, learner) = setup(2, 3, 2, 15, 3);
        let (l1, l2, l3) = (learner.l1.clone(), learner.l2.clone(), learner.l3.clone());
        let x_ref = dense_factor_update(&l1, &l2, &l3, &data, 1);
        let theta = theta_dense(&learner.kernel(), &data.subsets).unwrap();
        let h = kron::mixed_weighted_trace(&theta, &l1, &l3, 2, 3, 2).unwrap();
        let theta_part = matmul::sandwich(&l2, &h.transpose(), &l2).unwrap();
        let e1 = SymEigen::new(&l1).unwrap();
        let e2 = SymEigen::new(&l2).unwrap();
        let e3 = SymEigen::new(&l3).unwrap();
        let wdiag = middle_b_diag(&e1.values, &e2.values, &e3.values);
        let b_part = reconstruct_diag(&e2.vectors, &wdiag);
        let mut x = theta_part;
        x -= &b_part;
        assert!(x.rel_diff(&x_ref) < 1e-8, "L2 update mismatch: {}", x.rel_diff(&x_ref));
    }

    #[test]
    fn grouped_l3_update_matches_dense_definition() {
        let (data, learner) = setup(2, 2, 3, 15, 5);
        let (l1, l2, l3) = (learner.l1.clone(), learner.l2.clone(), learner.l3.clone());
        let x_ref = dense_factor_update(&l1, &l2, &l3, &data, 2);
        let theta = theta_dense(&learner.kernel(), &data.subsets).unwrap();
        let a = kron::kron(&l1, &l2);
        let a2 = kron::weighted_block_sum(&theta, &a, 4, 3).unwrap();
        let l3a2l3 = matmul::sandwich(&l3, &a2, &l3).unwrap();
        let b3 = b2_matrix(&a, &l3).unwrap();
        let mut x = l3a2l3;
        x -= &b3;
        assert!(x.rel_diff(&x_ref) < 1e-8, "L3 update mismatch: {}", x.rel_diff(&x_ref));
    }

    #[test]
    fn ascent_and_pd_over_iterations() {
        let (data, mut learner) = setup(2, 3, 2, 25, 7);
        let mut prev = f64::NEG_INFINITY;
        for it in 0..10 {
            learner.step(&data).unwrap();
            let (l1, l2, l3) = learner.subkernels();
            assert!(cholesky::is_pd(l1), "L1 lost PD at iter {it}");
            assert!(cholesky::is_pd(l2), "L2 lost PD at iter {it}");
            assert!(cholesky::is_pd(l3), "L3 lost PD at iter {it}");
            let ll = crate::dpp::likelihood::log_likelihood(
                &learner.kernel(),
                &data.subsets,
            )
            .unwrap();
            assert!(ll >= prev - 1e-9, "descent at iter {it}: {prev} -> {ll}");
            prev = ll;
        }
    }

    #[test]
    fn learns_from_kron3_truth() {
        let (data, mut learner) = setup(3, 2, 2, 40, 9);
        let ll0 = crate::dpp::likelihood::log_likelihood(&learner.kernel(), &data.subsets)
            .unwrap();
        let r = learner.run(&data, 12, 0.0).unwrap();
        assert!(r.final_ll() > ll0 + 0.05, "{ll0} -> {}", r.final_ll());
    }
}
