//! Three-factor KRK-Picard — the paper's multiblock generalization
//! (§3.1.1): learning `L = L₁ ⊗ L₂ ⊗ L₃` by block-coordinate updates
//!
//! `(L_k)_{ij} ← (L_k)_{ij} + a·N_k/N ·
//!     Tr[(L₁⊗…⊗E_{ij}⊗…⊗L₃)(LΔL)]`.
//!
//! Implementation strategy: the outer factors are handled by *grouping* —
//! updating `L₁` treats `B = L₂⊗L₃` as a single second factor and reuses
//! the m = 2 machinery; symmetrically for `L₃` with `A = L₁⊗L₂`. Neither
//! grouped factor is ever materialized: the Θ-half contractions come from
//! [`crate::learn::stats::ThetaEngine`], which accumulates grouped-factor
//! entries as per-split products (`B[p,q] = L₂[j,j']·L₃[r,r']`) straight
//! from the `κ×κ` subset inverses, and the `(I+L)⁻¹`-half diagonals use
//! the *product spectrum* of Cor. 2.2 (`d_B = d₂ⱼ·d₃ₛ`) instead of
//! eigendecomposing the `(N₂N₃)×(N₂N₃)` grouped matrix. The *middle*
//! factor needs a genuinely new contraction (the engine's `Mid` op, the
//! oracle for which is [`crate::linalg::kron::mixed_weighted_trace`]):
//!
//! Note: the paper's §3.1.1 multiblock display writes the non-updated
//! slots as `L_l`; consistency with Prop. 3.1 (whose m = 2 trace carries
//! `I ⊗ S₂`, `S₂ = L₂⁻¹`) requires the **inverses** `L_l⁻¹` there — the
//! as-printed form does not reduce to Eq. 7 at m = 2. We implement the
//! consistent form and verify each factor update against the dense
//! definition `Tr[(L₁⁻¹⊗E_{ij}⊗L₃⁻¹)(LΔL)]` in the tests below.
//!
//! - Θ-half: `Tr[(L₁⁻¹⊗E_{pq}⊗L₃⁻¹)·LΘL] = (L₂·Hᵀ·L₂)[p,q]` with
//!   `H[j',j] = Σ W₁[i,i']W₃[r,r']·Θ[(i',j',r'),(i,j,r)]`, `W₁ = L₁`,
//!   `W₃ = L₃` (cyclic trace + mixed-product identities);
//! - `(I+L)⁻¹`-half: in the joint eigenbasis it collapses to
//!   `P₂·diag(W)·P₂ᵀ` with
//!   `W[m] = Σ_{k,s} d₁ₖ·d₂ₘ²·d₃ₛ/(1+d₁ₖd₂ₘd₃ₛ)` — see `middle_b_diag`.
//!
//! Per iteration: `O(nκ³ + nκ²)` for the three Θ-half sweeps plus
//! `O(N₁³ + N₂³ + N₃³)` factor eigensolves and `O(N)` spectrum sums — no
//! `O(N²)` term and no `N×N` Θ anywhere (the m = 3 regime the paper
//! targets in §4, where three factors make sampling linear in `N`).

use crate::dpp::Kernel;
use crate::error::{Error, Result};
use crate::learn::krk::{apply_step_into, reconstruct_diag_into, KrkScratch};
use crate::learn::stats::{
    logdet_lpi_kron3, Contraction, KernelRef, KernelShape, StatsCache, ThetaEngine,
};
use crate::learn::traits::{Learner, TrainingSet};
use crate::linalg::eigen::{self, SymEigenScratch};
use crate::linalg::{matmul, Matrix};

/// KRK-Picard for `L = L₁ ⊗ L₂ ⊗ L₃`.
pub struct Krk3Picard {
    l1: Matrix,
    l2: Matrix,
    l3: Matrix,
    /// Step size `a`.
    pub step_size: f64,
    engine: ThetaEngine,
    cache: StatsCache,
    scratch: KrkScratch,
    /// Third eigensolver scratch (KrkScratch carries two).
    e3: SymEigenScratch,
    /// `Hᵀ` staging buffer of the middle update.
    ht: Matrix,
}

impl Krk3Picard {
    pub fn new(l1: Matrix, l2: Matrix, l3: Matrix, step_size: f64) -> Result<Self> {
        if !l1.is_square() || !l2.is_square() || !l3.is_square() {
            return Err(Error::Shape("krk3: sub-kernels must be square".into()));
        }
        Ok(Krk3Picard {
            l1,
            l2,
            l3,
            step_size,
            engine: ThetaEngine::new(),
            cache: StatsCache::default(),
            scratch: KrkScratch::default(),
            e3: SymEigenScratch::default(),
            ht: Matrix::zeros(0, 0),
        })
    }

    pub fn dims(&self) -> (usize, usize, usize) {
        (self.l1.rows(), self.l2.rows(), self.l3.rows())
    }

    pub fn subkernels(&self) -> (&Matrix, &Matrix, &Matrix) {
        (&self.l1, &self.l2, &self.l3)
    }

    fn shape(&self) -> KernelShape {
        let (n1, n2, n3) = self.dims();
        KernelShape::Kron3 { n1, n2, n3 }
    }

    /// Update L₁ by grouping `B = L₂⊗L₃` (m = 2 machinery, Prop. 3.1) —
    /// `A₁` from the engine, `B`-half from the product spectrum.
    fn update_l1(&mut self, data: &TrainingSet) -> Result<()> {
        let (_, n2, n3) = self.dims();
        {
            let stats = self.cache.get(&data.subsets, self.shape())?;
            self.engine.contract(
                KernelRef::Kron3(&self.l1, &self.l2, &self.l3),
                stats,
                Contraction::A1,
                &mut self.scratch.contr,
            )?;
        }
        let s = &mut self.scratch;
        matmul::sandwich_into(&mut s.sand, &self.l1, &s.contr, &self.l1, &mut s.tmp, &mut s.gemm)?;
        // Factor all three sub-kernels once; the later updates in this
        // step re-factor only the factor that changed (5 eigensolves per
        // iteration instead of 9).
        eigen::factor_into(&self.l1, &mut s.e1)?;
        eigen::factor_into(&self.l2, &mut s.e2)?;
        eigen::factor_into(&self.l3, &mut self.e3)?;
        grouped_l1_bdiag_into(&s.e1.values, &s.e2.values, &self.e3.values, &mut s.diag);
        reconstruct_diag_into(&s.e1.vectors, &s.diag, &mut s.bmat, &mut s.tmp, &mut s.gemm);
        s.sand -= &s.bmat;
        apply_step_into(
            &mut self.l1,
            &s.sand,
            self.step_size / (n2 * n3) as f64,
            1.0 / (n2 * n3) as f64,
            true,
            &mut s.candidate,
            &mut s.cholwork,
        );
        Ok(())
    }

    /// Update L₃ by grouping `A = L₁⊗L₂` (never materialized).
    fn update_l3(&mut self, data: &TrainingSet) -> Result<()> {
        let (n1, n2, _) = self.dims();
        {
            let stats = self.cache.get(&data.subsets, self.shape())?;
            self.engine.contract(
                KernelRef::Kron3(&self.l1, &self.l2, &self.l3),
                stats,
                Contraction::A2,
                &mut self.scratch.contr,
            )?;
        }
        let s = &mut self.scratch;
        matmul::sandwich_into(&mut s.sand, &self.l3, &s.contr, &self.l3, &mut s.tmp, &mut s.gemm)?;
        // Only L₂ changed since `update_l2` re-factored e1; e1/e3 are
        // current (see the step-order invariant in `update_l2`).
        eigen::factor_into(&self.l2, &mut s.e2)?;
        grouped_l3_bdiag_into(&s.e1.values, &s.e2.values, &self.e3.values, &mut s.diag);
        reconstruct_diag_into(&self.e3.vectors, &s.diag, &mut s.bmat, &mut s.tmp, &mut s.gemm);
        s.sand -= &s.bmat;
        apply_step_into(
            &mut self.l3,
            &s.sand,
            self.step_size / (n1 * n2) as f64,
            1.0 / (n1 * n2) as f64,
            true,
            &mut s.candidate,
            &mut s.cholwork,
        );
        Ok(())
    }

    /// Update the middle factor L₂ via the mixed contraction (engine `Mid`).
    fn update_l2(&mut self, data: &TrainingSet) -> Result<()> {
        let (n1, _, n3) = self.dims();
        {
            let stats = self.cache.get(&data.subsets, self.shape())?;
            // Θ-half: H with weights L₁, L₃ (from L·(L₁⁻¹⊗E⊗L₃⁻¹)·L =
            // L₁⊗L₂EL₂⊗L₃ under the cyclic trace), then L₂·Hᵀ·L₂.
            self.engine.contract(
                KernelRef::Kron3(&self.l1, &self.l2, &self.l3),
                stats,
                Contraction::Mid,
                &mut self.scratch.contr,
            )?;
        }
        let s = &mut self.scratch;
        s.contr.transpose_into(&mut self.ht);
        matmul::sandwich_into(&mut s.sand, &self.l2, &self.ht, &self.l2, &mut s.tmp, &mut s.gemm)?;
        // (I+L)⁻¹-half: P₂ diag(W) P₂ᵀ in the middle eigenbasis. Only L₁
        // changed since `update_l1` factored all three sub-kernels, so only
        // e1 is re-factored here (step order invariant: L₁ → L₂ → L₃).
        eigen::factor_into(&self.l1, &mut s.e1)?;
        middle_b_diag_into(&s.e1.values, &s.e2.values, &self.e3.values, &mut s.diag);
        reconstruct_diag_into(&s.e2.vectors, &s.diag, &mut s.bmat, &mut s.tmp, &mut s.gemm);
        s.sand -= &s.bmat;
        apply_step_into(
            &mut self.l2,
            &s.sand,
            self.step_size / (n1 * n3) as f64,
            1.0 / (n1 * n3) as f64,
            true,
            &mut s.candidate,
            &mut s.cholwork,
        );
        Ok(())
    }
}

/// Grouped-L₁ `(I+L)⁻¹` diagonal: `d₁ₖ²·Qₖ` with
/// `Qₖ = Σ_{j,s} d₂ⱼd₃ₛ/(1 + d₁ₖ·d₂ⱼd₃ₛ)` — the m = 2 `l1_b_l1` diagonal
/// against `B = L₂⊗L₃`, whose spectrum is the products `d₂ⱼ·d₃ₛ`
/// (Cor. 2.2); `O(N)` instead of an `(N₂N₃)³` eigensolve.
pub(crate) fn grouped_l1_bdiag_into(d1: &[f64], d2: &[f64], d3: &[f64], out: &mut Vec<f64>) {
    out.clear();
    out.resize(d1.len(), 0.0);
    for (k, dk) in out.iter_mut().enumerate() {
        let d1k = d1[k];
        let mut q = 0.0;
        for &dj in d2 {
            for &ds in d3 {
                let db = dj * ds;
                q += db / (1.0 + d1k * db);
            }
        }
        *dk = d1k * d1k * q;
    }
}

/// Grouped-L₃ `(I+L)⁻¹` diagonal: the m = 2 `b2_matrix` diagonal against
/// `A = L₁⊗L₂`, via the product spectrum `d_A = d₁ᵢ·d₂ⱼ`:
/// `W[r] = Σ_{i,j} d₁ᵢd₂ⱼ·d₃ᵣ²/(1 + d₁ᵢd₂ⱼ·d₃ᵣ)`.
pub(crate) fn grouped_l3_bdiag_into(d1: &[f64], d2: &[f64], d3: &[f64], out: &mut Vec<f64>) {
    out.clear();
    out.resize(d3.len(), 0.0);
    for (r, dr) in out.iter_mut().enumerate() {
        let d3r = d3[r];
        let mut sum = 0.0;
        for &di in d1 {
            for &dj in d2 {
                let da = di * dj;
                sum += da * d3r * d3r / (1.0 + da * d3r);
            }
        }
        *dr = sum;
    }
}

/// Middle-factor `(I+L)⁻¹` diagonal:
/// `W[m] = Σ_{k,s} d₁ₖ·d₂ₘ²·d₃ₛ/(1 + d₁ₖd₂ₘd₃ₛ)`
/// — from `Tr[(L₁⁻¹⊗E_{pq}⊗L₃⁻¹)·L(I+L)⁻¹L]` in the joint eigenbasis:
/// `Pᵀ(L₁⁻¹⊗E⊗L₃⁻¹)P = D₁⁻¹ ⊗ (P₂ᵀEP₂) ⊗ D₃⁻¹`, and `L(I+L)⁻¹L` has
/// eigenvalues `λ²/(1+λ)` with `λ = d₁ₖd₂ₘd₃ₛ`, so the trace collects
/// `λ²/((1+λ)·d₁ₖd₃ₛ) = d₁ₖd₂ₘ²d₃ₛ/(1+λ)` per `(k,s)` pair.
pub(crate) fn middle_b_diag_into(d1: &[f64], d2: &[f64], d3: &[f64], out: &mut Vec<f64>) {
    out.clear();
    out.resize(d2.len(), 0.0);
    for (m, dm_out) in out.iter_mut().enumerate() {
        let dm = d2[m];
        let mut acc = 0.0;
        for &dk in d1 {
            for &ds in d3 {
                let lam = dk * dm * ds;
                acc += dk * dm * dm * ds / (1.0 + lam);
            }
        }
        *dm_out = acc;
    }
}

/// Allocating form of [`middle_b_diag_into`] (test oracle assembly).
#[cfg(test)]
fn middle_b_diag(d1: &[f64], d2: &[f64], d3: &[f64]) -> Vec<f64> {
    let mut out = Vec::new();
    middle_b_diag_into(d1, d2, d3, &mut out);
    out
}

impl Learner for Krk3Picard {
    fn name(&self) -> &'static str {
        "krk3-picard"
    }

    fn step(&mut self, data: &TrainingSet) -> Result<()> {
        // Θ-statistics are recomputed per factor update (block-coordinate,
        // as in the m = 2 Alg. 1) — each is one Θ-free engine sweep.
        self.update_l1(data)?;
        self.update_l2(data)?;
        self.update_l3(data)?;
        Ok(())
    }

    fn objective(&mut self, data: &TrainingSet) -> Result<f64> {
        if data.subsets.is_empty() {
            return Ok(0.0);
        }
        let stats = self.cache.get(&data.subsets, self.shape())?;
        let data_term = self
            .engine
            .sum_logdet(KernelRef::Kron3(&self.l1, &self.l2, &self.l3), stats)?;
        eigen::factor_into(&self.l1, &mut self.scratch.e1)?;
        eigen::factor_into(&self.l2, &mut self.scratch.e2)?;
        eigen::factor_into(&self.l3, &mut self.e3)?;
        Ok(data_term
            - logdet_lpi_kron3(
                &self.scratch.e1.values,
                &self.scratch.e2.values,
                &self.e3.values,
            )?)
    }

    fn kernel(&self) -> Kernel {
        Kernel::Kron3(self.l1.clone(), self.l2.clone(), self.l3.clone())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dpp::likelihood::theta_dense;
    use crate::dpp::Sampler;
    use crate::learn::krk::reconstruct_diag;
    use crate::learn::stats::CompressedTraining;
    use crate::linalg::eigen::SymEigen;
    use crate::linalg::{cholesky, kron};
    use crate::rng::Rng;

    fn sub_kernel(n: usize, rng: &mut Rng) -> Matrix {
        let mut l = rng.paper_init_kernel(n);
        l.scale_mut(1.2 / n as f64);
        l.add_diag_mut(0.35);
        l
    }

    fn setup(
        n1: usize,
        n2: usize,
        n3: usize,
        count: usize,
        seed: u64,
    ) -> (TrainingSet, Krk3Picard) {
        let mut rng = Rng::new(seed);
        let truth = Kernel::Kron3(
            sub_kernel(n1, &mut rng),
            sub_kernel(n2, &mut rng),
            sub_kernel(n3, &mut rng),
        );
        let sampler = Sampler::new(&truth).unwrap();
        let subsets: Vec<Vec<usize>> =
            (0..count).map(|_| sampler.sample(&mut rng)).collect();
        let data = TrainingSet::new(n1 * n2 * n3, subsets).unwrap();
        let learner = Krk3Picard::new(
            sub_kernel(n1, &mut rng),
            sub_kernel(n2, &mut rng),
            sub_kernel(n3, &mut rng),
            1.0,
        )
        .unwrap();
        (data, learner)
    }

    /// Engine A-contraction for one factor on a fresh engine (test helper).
    fn engine_contract(
        l1: &Matrix,
        l2: &Matrix,
        l3: &Matrix,
        data: &TrainingSet,
        op: Contraction,
    ) -> Matrix {
        let (n1, n2, n3) = (l1.rows(), l2.rows(), l3.rows());
        let stats = CompressedTraining::new(
            &data.subsets,
            KernelShape::Kron3 { n1, n2, n3 },
        )
        .unwrap();
        let mut eng = ThetaEngine::new();
        let mut out = Matrix::zeros(0, 0);
        eng.contract(KernelRef::Kron3(l1, l2, l3), &stats, op, &mut out).unwrap();
        out
    }

    /// Dense reference for one factor update via the (Prop.-3.1-consistent)
    /// multiblock formula: `X_{ij} = Tr[(…L⁻¹…⊗E_{ij}⊗…L⁻¹…)(LΔL)]`
    /// computed literally.
    fn dense_factor_update(
        l1: &Matrix,
        l2: &Matrix,
        l3: &Matrix,
        data: &TrainingSet,
        factor: usize,
    ) -> Matrix {
        let kernel = Kernel::Kron3(l1.clone(), l2.clone(), l3.clone());
        let l = kernel.to_dense();
        let theta = theta_dense(&kernel, &data.subsets).unwrap();
        let mut lpi = l.clone();
        lpi.add_diag_mut(1.0);
        let inv = cholesky::inverse_pd(&lpi).unwrap();
        let mut delta = theta;
        delta -= &inv;
        let ldl = matmul::sandwich(&l, &delta, &l).unwrap();
        let nk = [l1.rows(), l2.rows(), l3.rows()][factor];
        let mut x = Matrix::zeros(nk, nk);
        for i in 0..nk {
            for j in 0..nk {
                let mut e = Matrix::zeros(nk, nk);
                e.set(i, j, 1.0);
                let inv1 = cholesky::inverse_pd(l1).unwrap();
                let inv2 = cholesky::inverse_pd(l2).unwrap();
                let inv3 = cholesky::inverse_pd(l3).unwrap();
                let probe = match factor {
                    0 => kron::kron3(&e, &inv2, &inv3),
                    1 => kron::kron3(&inv1, &e, &inv3),
                    _ => kron::kron3(&inv1, &inv2, &e),
                };
                // Tr[probe · LΔL]
                let mut tr = 0.0;
                let n = probe.rows();
                for r in 0..n {
                    tr += matmul::dot(probe.row(r), {
                        // column r of ldl == row r (LΔL is symmetric since
                        // L, Δ are) — use row.
                        ldl.row(r)
                    });
                }
                x.set(i, j, tr);
            }
        }
        x
    }

    #[test]
    fn grouped_l1_update_matches_dense_definition() {
        let (data, learner) = setup(2, 3, 2, 15, 1);
        let (l1, l2, l3) = (learner.l1.clone(), learner.l2.clone(), learner.l3.clone());
        let x_ref = dense_factor_update(&l1, &l2, &l3, &data, 0);
        // Efficient path pieces, exactly as `update_l1` assembles them:
        let a1 = engine_contract(&l1, &l2, &l3, &data, Contraction::A1);
        let l1a1l1 = matmul::sandwich(&l1, &a1, &l1).unwrap();
        let e1 = SymEigen::new(&l1).unwrap();
        let e2 = SymEigen::new(&l2).unwrap();
        let e3 = SymEigen::new(&l3).unwrap();
        let mut diag = Vec::new();
        grouped_l1_bdiag_into(&e1.values, &e2.values, &e3.values, &mut diag);
        let l1bl1 = reconstruct_diag(&e1.vectors, &diag);
        let mut x = l1a1l1;
        x -= &l1bl1;
        assert!(x.rel_diff(&x_ref) < 1e-8, "L1 update mismatch: {}", x.rel_diff(&x_ref));
    }

    #[test]
    fn middle_l2_update_matches_dense_definition() {
        let (data, learner) = setup(2, 3, 2, 15, 3);
        let (l1, l2, l3) = (learner.l1.clone(), learner.l2.clone(), learner.l3.clone());
        let x_ref = dense_factor_update(&l1, &l2, &l3, &data, 1);
        let h = engine_contract(&l1, &l2, &l3, &data, Contraction::Mid);
        let theta_part = matmul::sandwich(&l2, &h.transpose(), &l2).unwrap();
        let e1 = SymEigen::new(&l1).unwrap();
        let e2 = SymEigen::new(&l2).unwrap();
        let e3 = SymEigen::new(&l3).unwrap();
        let wdiag = middle_b_diag(&e1.values, &e2.values, &e3.values);
        let b_part = reconstruct_diag(&e2.vectors, &wdiag);
        let mut x = theta_part;
        x -= &b_part;
        assert!(x.rel_diff(&x_ref) < 1e-8, "L2 update mismatch: {}", x.rel_diff(&x_ref));
    }

    #[test]
    fn grouped_l3_update_matches_dense_definition() {
        let (data, learner) = setup(2, 2, 3, 15, 5);
        let (l1, l2, l3) = (learner.l1.clone(), learner.l2.clone(), learner.l3.clone());
        let x_ref = dense_factor_update(&l1, &l2, &l3, &data, 2);
        let a2 = engine_contract(&l1, &l2, &l3, &data, Contraction::A2);
        let l3a2l3 = matmul::sandwich(&l3, &a2, &l3).unwrap();
        let e1 = SymEigen::new(&l1).unwrap();
        let e2 = SymEigen::new(&l2).unwrap();
        let e3 = SymEigen::new(&l3).unwrap();
        let mut diag = Vec::new();
        grouped_l3_bdiag_into(&e1.values, &e2.values, &e3.values, &mut diag);
        let b3 = reconstruct_diag(&e3.vectors, &diag);
        let mut x = l3a2l3;
        x -= &b3;
        assert!(x.rel_diff(&x_ref) < 1e-8, "L3 update mismatch: {}", x.rel_diff(&x_ref));
    }

    #[test]
    fn grouped_bdiags_match_dense_grouped_spectra() {
        // The product-spectrum diagonals must agree with literally
        // eigendecomposing the grouped factors (the pre-engine path).
        let mut rng = Rng::new(17);
        let l1 = sub_kernel(2, &mut rng);
        let l2 = sub_kernel(3, &mut rng);
        let l3 = sub_kernel(2, &mut rng);
        let e1 = SymEigen::new(&l1).unwrap();
        let e2 = SymEigen::new(&l2).unwrap();
        let e3 = SymEigen::new(&l3).unwrap();
        // L1 grouping: B = L2⊗L3.
        let b = kron::kron(&l2, &l3);
        let dense = crate::learn::krk::l1_b_l1(&l1, &b).unwrap();
        let mut diag = Vec::new();
        grouped_l1_bdiag_into(&e1.values, &e2.values, &e3.values, &mut diag);
        let spec = reconstruct_diag(&e1.vectors, &diag);
        assert!(spec.rel_diff(&dense) < 1e-9, "{}", spec.rel_diff(&dense));
        // L3 grouping: A = L1⊗L2.
        let a = kron::kron(&l1, &l2);
        let dense3 = crate::learn::krk::b2_matrix(&a, &l3).unwrap();
        grouped_l3_bdiag_into(&e1.values, &e2.values, &e3.values, &mut diag);
        let spec3 = reconstruct_diag(&e3.vectors, &diag);
        assert!(spec3.rel_diff(&dense3) < 1e-9, "{}", spec3.rel_diff(&dense3));
    }

    #[test]
    fn ascent_and_pd_over_iterations() {
        let (data, mut learner) = setup(2, 3, 2, 25, 7);
        let mut prev = f64::NEG_INFINITY;
        for it in 0..10 {
            learner.step(&data).unwrap();
            let (l1, l2, l3) = learner.subkernels();
            assert!(cholesky::is_pd(l1), "L1 lost PD at iter {it}");
            assert!(cholesky::is_pd(l2), "L2 lost PD at iter {it}");
            assert!(cholesky::is_pd(l3), "L3 lost PD at iter {it}");
            let ll = crate::dpp::likelihood::log_likelihood(
                &learner.kernel(),
                &data.subsets,
            )
            .unwrap();
            assert!(ll >= prev - 1e-9, "descent at iter {it}: {prev} -> {ll}");
            prev = ll;
        }
    }

    #[test]
    fn fused_objective_matches_dense_likelihood() {
        let (data, mut learner) = setup(2, 3, 2, 20, 11);
        let dense = crate::dpp::likelihood::log_likelihood(
            &learner.kernel(),
            &data.subsets,
        )
        .unwrap();
        let fused = learner.objective(&data).unwrap();
        assert!((fused - dense).abs() < 1e-9, "{fused} vs {dense}");
    }

    #[test]
    fn learns_from_kron3_truth() {
        let (data, mut learner) = setup(3, 2, 2, 40, 9);
        let ll0 = crate::dpp::likelihood::log_likelihood(&learner.kernel(), &data.subsets)
            .unwrap();
        let r = learner.run(&data, 12, 0.0).unwrap();
        assert!(r.final_ll() > ll0 + 0.05, "{ll0} -> {}", r.final_ll());
    }
}
