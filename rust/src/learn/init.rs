//! Kernel initializers matching the paper's experimental protocols (§5).

use crate::error::Result;
use crate::linalg::{cholesky, eigen::SymEigen, nkp, Matrix};
use crate::rng::Rng;

/// §5.1: sub-kernel `L_i = XᵀX` with `X` uniform in `[0, √2)`, scaled so
/// the Kron product has a moderate spectrum at ground-set size `n1·n2`
/// (the raw paper init grows like `n²`; we normalize per sub-kernel by its
/// size, which keeps expected subset sizes in a workable range at every N
/// while preserving the XᵀX structure).
pub fn paper_subkernel(n: usize, rng: &mut Rng) -> Matrix {
    let mut l = rng.paper_init_kernel(n);
    l.scale_mut(2.0 / n as f64);
    l.add_diag_mut(0.05);
    l
}

/// §5.2: Wishart-initialized *marginal* kernel for EM:
/// `K ~ Wishart(N, I)/N`, spectrum clamped into (0,1).
pub fn wishart_marginal(n: usize, rng: &mut Rng) -> Result<Matrix> {
    let w = rng.wishart(n, n as f64, 1.0 / n as f64);
    let eig = SymEigen::new(&w)?;
    let vals: Vec<f64> = eig.values.iter().map(|&v| v.clamp(1e-4, 1.0 - 1e-4)).collect();
    Ok(crate::learn::krk::reconstruct_diag(&eig.vectors, &vals))
}

/// §5.2: DPP kernel from a marginal kernel, `L = K(I−K)⁻¹`
/// = `V·diag(λ/(1−λ))·Vᵀ`.
pub fn l_from_marginal(k: &Matrix) -> Result<Matrix> {
    let eig = SymEigen::new(k)?;
    let vals: Vec<f64> = eig
        .values
        .iter()
        .map(|&l| {
            let l = l.clamp(1e-6, 1.0 - 1e-6);
            l / (1.0 - l)
        })
        .collect();
    Ok(crate::learn::krk::reconstruct_diag(&eig.vectors, &vals))
}

/// §5.2: KronDPP init "as in Joint-Picard": `(L₁, L₂)` minimizing
/// `‖L − L₁⊗L₂‖_F` with balanced norms and PD factors.
pub fn subkernels_from_dense(l: &Matrix, n1: usize, n2: usize) -> Result<(Matrix, Matrix)> {
    let (mut l1, mut l2) = nkp::nearest_kronecker_pd(l, n1, n2, 500, 1e-12)?;
    // The NKP of a PD matrix can be PSD-boundary; nudge if needed.
    for m in [&mut l1, &mut l2] {
        if !cholesky::is_pd(m) {
            let eig = SymEigen::new(m)?;
            let floor = eig.max_eig().abs() * 1e-8 + 1e-12;
            let shift = (-eig.min_eig()).max(0.0) + floor;
            m.add_diag_mut(shift);
        }
    }
    Ok((l1, l2))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::kron::kron;

    #[test]
    fn paper_subkernel_pd() {
        let mut rng = Rng::new(1);
        for n in [5, 20, 50] {
            assert!(cholesky::is_pd(&paper_subkernel(n, &mut rng)));
        }
    }

    #[test]
    fn wishart_marginal_spectrum_in_unit_interval() {
        let mut rng = Rng::new(2);
        let k = wishart_marginal(12, &mut rng).unwrap();
        let eig = SymEigen::new(&k).unwrap();
        assert!(eig.min_eig() > 0.0);
        assert!(eig.max_eig() < 1.0);
    }

    #[test]
    fn l_from_marginal_roundtrip() {
        // K = L(L+I)^{-1} recovered from L built from K.
        let mut rng = Rng::new(3);
        let k = wishart_marginal(8, &mut rng).unwrap();
        let l = l_from_marginal(&k).unwrap();
        let marg = crate::dpp::Kernel::Full(l).marginal_kernel().unwrap();
        assert!(marg.rel_diff(&k) < 1e-8);
    }

    #[test]
    fn subkernels_from_dense_pd_and_close() {
        let mut rng = Rng::new(4);
        let a = paper_subkernel(3, &mut rng);
        let b = paper_subkernel(4, &mut rng);
        let mut l = kron(&a, &b);
        l.add_diag_mut(0.01); // not exactly Kronecker
        let (l1, l2) = subkernels_from_dense(&l, 3, 4).unwrap();
        assert!(cholesky::is_pd(&l1));
        assert!(cholesky::is_pd(&l2));
        assert!(kron(&l1, &l2).rel_diff(&l) < 0.05);
    }
}
