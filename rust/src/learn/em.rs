//! The EM baseline of Gillenwater, Kulesza, Fox & Taskar, NIPS 2014
//! (ref. [10] of the paper) — used in the Table-1 comparison (§5.2).
//!
//! EM works on the *marginal* kernel `K = V·diag(λ)·Vᵀ` with latent
//! variable `J` (the elementary-DPP index set: `j ∈ J` w.p. `λ_j`).
//!
//! **E-step (exact).** Using the closed form
//! `P(Y) = |det(K − I_Ȳ)|` for the probability that the sampled set is
//! exactly `Y`, tilting eigenvalue `j` by `t` (which perturbs both the
//! `λ_j` and `1−λ_j` mixture factors) and differentiating at `t = 1`
//! gives the posterior inclusion probability
//!
//! ```text
//! p_{ij} = P(j ∈ J | Y_i) = λ_j + λ_j(1−λ_j) · v_jᵀ (K − I_{Ȳ_i})⁻¹ v_j
//! ```
//!
//! (verified against exhaustive enumeration in the tests below)
//!
//! **M-step.** Eigenvalues have the exact update
//! `λ_j ← (1/n) Σ_i p_{ij}` (posterior mean of the Bernoulli prior);
//! eigenvectors take a line-searched ascent step along the Euclidean
//! gradient `G = (2/n) Σ_i (K−I_{Ȳ_i})⁻¹ V Λ`, retracted to the Stiefel
//! manifold by QR — the same E-exact / M-ascent structure as [10].
//!
//! Complexity `O(n·N³)` per iteration; EM is only run at the paper's
//! Table-1 scale (N = 100).

use crate::dpp::Kernel;
use crate::error::{Error, Result};
use crate::learn::traits::{Learner, TrainingSet};
use crate::linalg::{eigen::SymEigen, lu::Lu, matmul, qr::Qr, Matrix};

const LAMBDA_MIN: f64 = 1e-6;
const LAMBDA_MAX: f64 = 1.0 - 1e-6;

/// EM learner over the marginal kernel.
pub struct EmLearner {
    /// Orthonormal eigenvectors (columns).
    v: Matrix,
    /// Eigenvalues in (0, 1).
    lambda: Vec<f64>,
    /// Initial eigenvector step size for the line search.
    pub eigvec_step: f64,
}

impl EmLearner {
    /// Initialize from a marginal kernel `K` (must have spectrum in (0,1);
    /// eigenvalues are clamped away from {0, 1}).
    pub fn from_marginal(k: &Matrix) -> Result<Self> {
        if !k.is_square() {
            return Err(Error::Shape("em: K must be square".into()));
        }
        let eig = SymEigen::new(k)?;
        let lambda: Vec<f64> =
            eig.values.iter().map(|&l| l.clamp(LAMBDA_MIN, LAMBDA_MAX)).collect();
        Ok(EmLearner { v: eig.vectors, lambda, eigvec_step: 1.0 })
    }

    /// Current marginal kernel `K`.
    pub fn marginal(&self) -> Matrix {
        crate::learn::krk::reconstruct_diag(&self.v, &self.lambda)
    }

    /// Current eigenvalues.
    pub fn eigenvalues(&self) -> &[f64] {
        &self.lambda
    }

    /// Mean log-likelihood under the marginal parametrization:
    /// `(1/n) Σ log |det(K − I_{Ȳ_i})|`.
    pub fn marginal_log_likelihood(&self, data: &TrainingSet) -> Result<f64> {
        let k = self.marginal();
        let mut total = 0.0;
        for y in &data.subsets {
            let m = k_minus_i_complement(&k, y);
            let (_, logabs) = Lu::factor(&m)?.slogdet();
            total += logabs;
        }
        Ok(total / data.len().max(1) as f64)
    }

    /// E-step + exact λ M-step + eigenvector ascent (one EM iteration).
    fn em_step(&mut self, data: &TrainingSet) -> Result<()> {
        let n = self.v.rows();
        let k = self.marginal();
        let count = data.len();
        let mut lambda_new = vec![0.0f64; n];
        // Gradient accumulator for the eigenvector step: (2/n) Σ W_i V Λ.
        let mut grad = Matrix::zeros(n, n);
        // Per-subset product buffer + GEMM pack buffers, reused across the
        // whole E-step sweep.
        let mut wv = Matrix::zeros(0, 0);
        let mut gemm = matmul::GemmScratch::new();
        for y in &data.subsets {
            let m = k_minus_i_complement(&k, y);
            let w = Lu::factor(&m)?.inverse();
            // p_ij = λ_j + λ_j(1−λ_j)·v_jᵀWv_j via diag(VᵀWV).
            matmul::matmul_into(&mut wv, &w, &self.v, &mut gemm)?;
            for j in 0..n {
                let vj_wvj: f64 =
                    (0..n).map(|r| self.v.get(r, j) * wv.get(r, j)).sum();
                let lj = self.lambda[j];
                lambda_new[j] += lj + lj * (1.0 - lj) * vj_wvj;
            }
            grad += &wv; // fold Λ scaling and 2/n after the loop
        }
        for l in &mut lambda_new {
            *l = (*l / count as f64).clamp(LAMBDA_MIN, LAMBDA_MAX);
        }
        // grad = (2/n) (Σ W_i V) Λ  (with the OLD λ, matching the E-step).
        for i in 0..n {
            for j in 0..n {
                let g = grad.get(i, j) * 2.0 * self.lambda[j] / count as f64;
                grad.set(i, j, g);
            }
        }
        // Exact eigenvalue M-step.
        self.lambda = lambda_new;
        // Eigenvector ascent with backtracking line search + QR retraction.
        let base = self.marginal_log_likelihood(data)?;
        let mut eta = self.eigvec_step;
        for _ in 0..5 {
            let mut cand = self.v.clone();
            cand.axpy(eta, &grad)?;
            let retracted = qr_retract(&cand)?;
            let old_v = std::mem::replace(&mut self.v, retracted);
            let ll = self.marginal_log_likelihood(data)?;
            if ll >= base {
                return Ok(());
            }
            self.v = old_v;
            eta *= 0.25;
        }
        // No improving eigenvector step found; keep V (λ step already
        // improved the objective).
        Ok(())
    }
}

/// `K − I_Ȳ`: subtract 1 from the diagonal on the complement of `y`.
fn k_minus_i_complement(k: &Matrix, y: &[usize]) -> Matrix {
    let n = k.rows();
    let mut m = k.clone();
    let mut in_y = vec![false; n];
    for &i in y {
        in_y[i] = true;
    }
    for i in 0..n {
        if !in_y[i] {
            let v = m.get(i, i) - 1.0;
            m.set(i, i, v);
        }
    }
    m
}

/// QR-based retraction onto the orthogonal group with sign correction
/// (so the retraction is continuous at η → 0).
fn qr_retract(m: &Matrix) -> Result<Matrix> {
    let qr = Qr::factor(m)?;
    let mut q = qr.q;
    for j in 0..q.cols() {
        if qr.r.get(j, j) < 0.0 {
            for i in 0..q.rows() {
                let v = -q.get(i, j);
                q.set(i, j, v);
            }
        }
    }
    Ok(q)
}

impl Learner for EmLearner {
    fn name(&self) -> &'static str {
        "em"
    }

    fn step(&mut self, data: &TrainingSet) -> Result<()> {
        self.em_step(data)
    }

    /// The equivalent DPP kernel `L = K(I−K)⁻¹ = V·diag(λ/(1−λ))·Vᵀ`.
    fn kernel(&self) -> Kernel {
        let l_eigs: Vec<f64> = self.lambda.iter().map(|&l| l / (1.0 - l)).collect();
        Kernel::Full(crate::learn::krk::reconstruct_diag(&self.v, &l_eigs))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dpp::likelihood::log_prob;
    use crate::dpp::Sampler;
    use crate::rng::Rng;

    fn random_marginal(n: usize, seed: u64) -> Matrix {
        let mut rng = Rng::new(seed);
        let w = rng.wishart(n, n as f64, 1.0 / n as f64);
        // Normalize spectrum into (0,1): K = W(W+I)^{-1}.
        let eig = SymEigen::new(&w).unwrap();
        let vals: Vec<f64> = eig.values.iter().map(|&v| v.max(1e-4)).collect();
        let kvals: Vec<f64> = vals.iter().map(|&v| v / (1.0 + v)).collect();
        crate::learn::krk::reconstruct_diag(&eig.vectors, &kvals)
    }

    #[test]
    fn marginal_formula_matches_l_formula() {
        // |det(K − I_Ȳ)| = det(L_Y)/det(L+I) with L = K(I−K)^{-1}.
        let k = random_marginal(6, 1);
        let em = EmLearner::from_marginal(&k).unwrap();
        let kernel = em.kernel();
        for y in [vec![], vec![1usize, 4], vec![0, 2, 3, 5]] {
            let m = k_minus_i_complement(&em.marginal(), &y);
            let (_, logabs) = Lu::factor(&m).unwrap().slogdet();
            let via_l = log_prob(&kernel, &y).unwrap();
            assert!((logabs - via_l).abs() < 1e-7, "Y={y:?}: {logabs} vs {via_l}");
        }
    }

    #[test]
    fn posterior_matches_bruteforce() {
        // p_ij = λ_j v_jᵀ(K−I_Ȳ)⁻¹v_j against exhaustive enumeration of J.
        let n = 4;
        let k = random_marginal(n, 2);
        let em = EmLearner::from_marginal(&k).unwrap();
        let kmat = em.marginal();
        let y = vec![0usize, 2];
        // Brute force over all J ⊆ {0..4}: P(J)·P(Y|J).
        let mut post = vec![0.0f64; n];
        let mut total = 0.0;
        for mask in 0u32..(1 << n) {
            let j: Vec<usize> = (0..n).filter(|&b| mask >> b & 1 == 1).collect();
            if j.len() != y.len() {
                continue; // elementary DPP gives |Y| = |J|
            }
            let mut pj = 1.0;
            for b in 0..n {
                pj *= if mask >> b & 1 == 1 {
                    em.lambda[b]
                } else {
                    1.0 - em.lambda[b]
                };
            }
            // P(Y|J) = det([V_J V_Jᵀ]_Y)
            let vj = em.v.select_cols(&j);
            let kj = matmul::matmul_nt(&vj, &vj).unwrap();
            let pyj = crate::linalg::lu::det(&kj.principal_submatrix(&y)).unwrap();
            let w = pj * pyj;
            total += w;
            for &b in &j {
                post[b] += w;
            }
        }
        for p in &mut post {
            *p /= total;
        }
        // Formula.
        let m = k_minus_i_complement(&kmat, &y);
        let w = Lu::factor(&m).unwrap().inverse();
        for j in 0..n {
            let vj = em.v.col(j);
            let lj = em.lambda[j];
            let formula = lj + lj * (1.0 - lj) * w.quad_form(&vj).unwrap();
            assert!(
                (formula - post[j]).abs() < 1e-8,
                "j={j}: formula {formula} vs brute {}",
                post[j]
            );
        }
    }

    #[test]
    fn em_increases_likelihood() {
        let n = 8;
        let mut rng = Rng::new(3);
        let mut truth = rng.paper_init_kernel(n);
        truth.scale_mut(1.5 / n as f64);
        truth.add_diag_mut(0.4);
        let kernel = Kernel::Full(truth);
        let sampler = Sampler::new(&kernel).unwrap();
        let subsets: Vec<Vec<usize>> = (0..40).map(|_| sampler.sample(&mut rng)).collect();
        let data = TrainingSet::new(n, subsets).unwrap();
        let k0 = random_marginal(n, 4);
        let mut em = EmLearner::from_marginal(&k0).unwrap();
        let ll0 = em.marginal_log_likelihood(&data).unwrap();
        for _ in 0..8 {
            em.step(&data).unwrap();
        }
        let ll1 = em.marginal_log_likelihood(&data).unwrap();
        assert!(ll1 > ll0, "EM failed to improve: {ll0} -> {ll1}");
    }

    #[test]
    fn eigenvalues_stay_in_unit_interval() {
        let n = 6;
        let mut rng = Rng::new(5);
        let mut truth = rng.paper_init_kernel(n);
        truth.scale_mut(1.0 / n as f64);
        truth.add_diag_mut(0.4);
        let sampler = Sampler::new(&Kernel::Full(truth)).unwrap();
        let subsets: Vec<Vec<usize>> = (0..30).map(|_| sampler.sample(&mut rng)).collect();
        let data = TrainingSet::new(n, subsets).unwrap();
        let mut em = EmLearner::from_marginal(&random_marginal(n, 6)).unwrap();
        for _ in 0..6 {
            em.step(&data).unwrap();
            for &l in em.eigenvalues() {
                assert!((0.0..1.0).contains(&l), "λ = {l}");
            }
        }
    }

    #[test]
    fn retraction_is_orthonormal() {
        let mut rng = Rng::new(7);
        let m = rng.normal_matrix(6, 6);
        let q = qr_retract(&m).unwrap();
        let qtq = matmul::matmul_tn(&q, &q).unwrap();
        assert!(qtq.rel_diff(&Matrix::identity(6)) < 1e-10);
    }
}
