//! Low-rank DPP learning — the Gartrell–Paquet–Koenigstein baseline
//! (ref. [9] of the paper, arXiv:1602.05436).
//!
//! Parametrizes `L = V·Vᵀ` with `V ∈ R^{N×K}`, `K ≪ N`, and ascends the
//! log-likelihood by (stochastic) gradient steps on `V`. The paper
//! contrasts KronDPP against this model twice: [9] cannot assign mass to
//! subsets larger than `K` (rank ceiling), and its stochastic updates are
//! slower than KRK-Picard's (§3.1.2). Both properties are exercised in
//! the tests/benches.
//!
//! Gradient (from Eq. 3 with `L = VVᵀ`): per observed subset `Y`,
//! `∂/∂V [log det(V_Y V_Yᵀ)] = 2·U_Y (V_Y V_Yᵀ)⁻¹ V_Y` (rows scattered
//! back through `U_Y`), and the normalizer term uses the dual kernel
//! `C = VᵀV` (K×K):
//! `∂/∂V [−log det(I + VVᵀ)] = −2·V(I + C)⁻¹`,
//! so a full-gradient step costs `O(nκ²K + NK² + K³)` — no N³ anywhere,
//! but every step touches all N·K parameters (vs KRK's O(N) parameters).

use crate::dpp::Kernel;
use crate::error::{Error, Result};
use crate::learn::traits::{Learner, TrainingSet};
use crate::linalg::{cholesky::Cholesky, matmul, Matrix};
use crate::rng::Rng;

/// Low-rank DPP learner (`L = VVᵀ`).
pub struct LowRank {
    v: Matrix,
    /// Gradient step size.
    pub lr: f64,
    /// Minibatch size (0 = full batch).
    pub minibatch: usize,
    /// Ridge added to `L_Y` solves for numerical safety.
    pub ridge: f64,
    rng: Rng,
}

impl LowRank {
    /// Random initialization with `K` factors.
    pub fn init(n: usize, k: usize, lr: f64, seed: u64) -> Self {
        let mut rng = Rng::new(seed);
        let mut v = rng.normal_matrix(n, k);
        v.scale_mut(1.0 / (k as f64).sqrt());
        LowRank { v, lr, minibatch: 0, ridge: 1e-9, rng }
    }

    /// Start from a given factor matrix.
    pub fn from_factors(v: Matrix, lr: f64, seed: u64) -> Self {
        LowRank { v, lr, minibatch: 0, ridge: 1e-9, rng: Rng::new(seed) }
    }

    /// Rank `K`.
    pub fn rank(&self) -> usize {
        self.v.cols()
    }

    /// Borrow the factor matrix.
    pub fn factors(&self) -> &Matrix {
        &self.v
    }

    /// Mean-log-likelihood gradient over the given subset indices.
    fn gradient(&self, data: &TrainingSet, batch: &[usize]) -> Result<Matrix> {
        let (n, k) = self.v.shape();
        let mut grad = Matrix::zeros(n, k);
        let w = 2.0 / batch.len().max(1) as f64;
        for &bi in batch {
            let y = &data.subsets[bi];
            if y.is_empty() {
                continue;
            }
            if y.len() > k {
                return Err(Error::Invalid(format!(
                    "low-rank model (K={k}) observed subset of size {} — rank ceiling \
                     (the limitation §1 of the paper calls out for [9])",
                    y.len()
                )));
            }
            // V_Y (κ×K), G_Y = (V_Y V_Yᵀ + ridge·I)⁻¹ V_Y.
            let vy = self.v.select_rows(y);
            let mut lyy = matmul::matmul_nt(&vy, &vy)?;
            lyy.add_diag_mut(self.ridge);
            let g = Cholesky::factor(&lyy)?.solve_matrix(&vy)?;
            for (a, &row) in y.iter().enumerate() {
                matmul::axpy_slice(grad.row_mut(row), w, g.row(a));
            }
        }
        // Normalizer: −2·V(I + VᵀV)⁻¹ (dual form), shared across batch.
        let mut c = matmul::matmul_tn(&self.v, &self.v)?;
        c.add_diag_mut(1.0);
        let cinv = Cholesky::factor(&c)?.inverse();
        let norm_term = matmul::matmul(&self.v, &cinv)?;
        grad.axpy(-2.0, &norm_term)?;
        Ok(grad)
    }
}

impl Learner for LowRank {
    fn name(&self) -> &'static str {
        "lowrank-sgd"
    }

    fn step(&mut self, data: &TrainingSet) -> Result<()> {
        let batch: Vec<usize> = if self.minibatch == 0 {
            (0..data.len()).collect()
        } else {
            (0..self.minibatch).map(|_| self.rng.below(data.len())).collect()
        };
        let grad = self.gradient(data, &batch)?;
        self.v.axpy(self.lr, &grad)?;
        Ok(())
    }

    fn kernel(&self) -> Kernel {
        let mut l = matmul::gram_rows(&self.v);
        // PSD → PD for the likelihood/sampling plumbing.
        l.add_diag_mut(1e-9);
        Kernel::Full(l)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dpp::likelihood::log_likelihood;
    use crate::dpp::Sampler;

    fn problem(n: usize, k_truth: usize, count: usize, seed: u64) -> TrainingSet {
        let mut rng = Rng::new(seed);
        let x = rng.normal_matrix(n, k_truth);
        let mut l = matmul::gram_rows(&x);
        l.scale_mut(1.0 / k_truth as f64);
        l.add_diag_mut(1e-6);
        let sampler = Sampler::new(&Kernel::Full(l)).unwrap();
        let subsets: Vec<Vec<usize>> = (0..count)
            .map(|_| sampler.sample(&mut rng))
            .filter(|y| !y.is_empty())
            .collect();
        TrainingSet::new(n, subsets).unwrap()
    }

    #[test]
    fn gradient_matches_finite_differences() {
        let data = problem(8, 3, 10, 1);
        let learner = LowRank::init(8, 4, 0.1, 2);
        let grad = learner.gradient(&data, &(0..data.len()).collect::<Vec<_>>()).unwrap();
        let eps = 1e-6;
        let base_ll = |v: &Matrix| {
            let mut l = matmul::gram_rows(v);
            l.add_diag_mut(1e-9);
            log_likelihood(&Kernel::Full(l), &data.subsets).unwrap()
        };
        for (i, j) in [(0usize, 0usize), (3, 2), (7, 3)] {
            let mut vp = learner.v.clone();
            vp.set(i, j, vp.get(i, j) + eps);
            let mut vm = learner.v.clone();
            vm.set(i, j, vm.get(i, j) - eps);
            let fd = (base_ll(&vp) - base_ll(&vm)) / (2.0 * eps);
            assert!(
                (grad.get(i, j) - fd).abs() < 1e-4 * fd.abs().max(1.0),
                "grad[{i},{j}] = {} vs fd {fd}",
                grad.get(i, j)
            );
        }
    }

    #[test]
    fn full_batch_ascent_improves_likelihood() {
        let data = problem(12, 4, 30, 3);
        let mut learner = LowRank::init(12, 6, 0.05, 4);
        let ll0 = log_likelihood(&learner.kernel(), &data.subsets).unwrap();
        for _ in 0..40 {
            learner.step(&data).unwrap();
        }
        let ll1 = log_likelihood(&learner.kernel(), &data.subsets).unwrap();
        assert!(ll1 > ll0, "{ll0} -> {ll1}");
    }

    #[test]
    fn rank_ceiling_is_reported() {
        // Subsets bigger than K must error with the [9] limitation message.
        let data = TrainingSet::new(10, vec![vec![0, 1, 2, 3, 4]]).unwrap();
        let mut learner = LowRank::init(10, 3, 0.1, 5);
        let err = learner.step(&data).unwrap_err();
        assert!(err.to_string().contains("rank ceiling"));
    }

    #[test]
    fn stochastic_mode_runs_and_improves() {
        let data = problem(12, 4, 40, 7);
        let mut learner = LowRank::init(12, 6, 0.03, 8);
        learner.minibatch = 4;
        let ll0 = log_likelihood(&learner.kernel(), &data.subsets).unwrap();
        for _ in 0..120 {
            learner.step(&data).unwrap();
        }
        let ll1 = log_likelihood(&learner.kernel(), &data.subsets).unwrap();
        assert!(ll1 > ll0, "{ll0} -> {ll1}");
    }
}
