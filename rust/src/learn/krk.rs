//! KRK-Picard (Algorithm 1) — the paper's main contribution.
//!
//! Block-coordinate ascent on the sub-kernels of `L = L₁ ⊗ L₂`:
//!
//! ```text
//! L₁ ← L₁ + a·Tr₁((I ⊗ L₂⁻¹)(LΔL))/N₂
//! L₂ ← L₂ + a·Tr₂((L₁⁻¹ ⊗ I)(LΔL))/N₁
//! ```
//!
//! implemented *without materializing `LΔL`* per Appendix B:
//!
//! - the `Θ` half contracts to `L₁·A₁·L₁` with `A₁[k,l] = Tr(Θ_(kl)L₂)`
//!   (`O(N²)` dense, `O(κ²)` sparse/stochastic), and to `L₂·A₂·L₂` with
//!   `A₂ = Σ_{ij} L1_{ij}Θ_(ij)`;
//! - the `(I+L)⁻¹` half reduces to sub-eigenbasis diagonals:
//!   `L₁·B·L₁ = P₁·diag(d₁ₖ²·Qₖ)·P₁ᵀ`, `Qₖ = Σ_r d₂ᵣ/(1+d₁ₖd₂ᵣ)`, and
//!   `B₂ = P₂·diag_r(Σ_k d₁ₖd₂ᵣ²/(1+d₁ₖd₂ᵣ))·P₂ᵀ`.
//!
//! The Θ half never materializes Θ either: since both contractions are
//! *linear* in Θ, [`crate::learn::stats::ThetaEngine`] accumulates them
//! directly from the `κ×κ` subset inverses in `O(nκ²)` — dropping the
//! paper's `O(nκ³ + N²)` batch iteration (Thm. 3.3) to
//! `O(nκ³ + nκ² + N₁³ + N₂³)` time and `O(nκ + N₁² + N₂²)` extra space.
//! The same sweep returns `Σᵢ wᵢ·log det L_{Yᵢ}` for free, fusing
//! objective tracking into the gradient pass. With `a = 1` the iterates
//! stay PD and the likelihood is non-decreasing (Prop. 3.1 + Thm. 3.2).

use crate::dpp::Kernel;
use crate::error::{Error, Result};
use crate::learn::stats::{
    logdet_lpi_kron2, CompressedTraining, Contraction, KernelRef, KernelShape, StatsCache,
    ThetaEngine,
};
use crate::learn::traits::{Learner, TrainingSet};
use crate::linalg::eigen::{self, SymEigenScratch};
use crate::linalg::matmul::GemmScratch;
use crate::linalg::{kron, matmul, Matrix};

/// Pluggable backend for the two `O(N²)` Θ-contractions, so the PJRT
/// runtime (AOT-compiled JAX/Pallas artifacts) can take over the hot path;
/// see `crate::runtime::HloContractions`.
pub trait Contractions: Send + Sync {
    /// `A₁[k,l] = Tr(Θ_(kl) · L₂)`.
    fn block_trace(&self, theta: &Matrix, l2: &Matrix, n1: usize, n2: usize) -> Result<Matrix>;
    /// `A₂ = Σ_{ij} W[i,j] · Θ_(ij)`.
    fn weighted_block_sum(
        &self,
        theta: &Matrix,
        w: &Matrix,
        n1: usize,
        n2: usize,
    ) -> Result<Matrix>;
    /// [`Contractions::block_trace`] into a caller-held output. The default
    /// allocates through `block_trace`; backends with a true in-place path
    /// (the CPU backend) override it so learner steady state stays
    /// allocation-free.
    fn block_trace_into(
        &self,
        theta: &Matrix,
        l2: &Matrix,
        n1: usize,
        n2: usize,
        out: &mut Matrix,
    ) -> Result<()> {
        *out = self.block_trace(theta, l2, n1, n2)?;
        Ok(())
    }
    /// [`Contractions::weighted_block_sum`] into a caller-held output
    /// (default allocates; see [`Contractions::block_trace_into`]).
    fn weighted_block_sum_into(
        &self,
        theta: &Matrix,
        w: &Matrix,
        n1: usize,
        n2: usize,
        out: &mut Matrix,
    ) -> Result<()> {
        *out = self.weighted_block_sum(theta, w, n1, n2)?;
        Ok(())
    }

    /// Fused Θ-free entry point: contract compressed training statistics
    /// straight into `out` (the `A₁`/`A₂` of App. B) and return the fused
    /// data term `Σᵢ wᵢ·log det L_{Yᵢ}` — no dense Θ anywhere on the CPU
    /// path. The default synthesizes a dense Θ through
    /// [`ThetaEngine::theta_dense_into`] and routes it to the backend's
    /// Θ-contraction (so Θ-only backends like the PJRT runtime keep
    /// working unchanged, at their previous `O(N²)` cost);
    /// [`CpuContractions`] overrides it with the `O(nκ²)` engine sweep.
    /// m = 2 kernels only — the m = 3 learner drives the engine directly.
    fn contract_compressed(
        &self,
        kernel: KernelRef<'_>,
        stats: &CompressedTraining,
        engine: &mut ThetaEngine,
        op: Contraction,
        out: &mut Matrix,
    ) -> Result<f64> {
        let KernelRef::Kron2(l1, l2) = kernel else {
            return Err(Error::Invalid(
                "contract_compressed: default backend supports m = 2 kernels only".into(),
            ));
        };
        let (n1, n2) = (l1.rows(), l2.rows());
        let mut theta = Matrix::zeros(0, 0);
        let data_term = engine.theta_dense_into(kernel, stats, &mut theta)?;
        match op {
            Contraction::A1 => self.block_trace_into(&theta, l2, n1, n2, out)?,
            Contraction::A2 => self.weighted_block_sum_into(&theta, l1, n1, n2, out)?,
            Contraction::Mid => {
                return Err(Error::Invalid(
                    "contract_compressed: Mid is a three-factor contraction".into(),
                ))
            }
        }
        Ok(data_term)
    }
}

/// Pure-Rust contraction backend (cache-blocked, multithreaded).
pub struct CpuContractions;

impl Contractions for CpuContractions {
    fn block_trace(&self, theta: &Matrix, l2: &Matrix, n1: usize, n2: usize) -> Result<Matrix> {
        kron::block_trace(theta, l2, n1, n2)
    }
    fn weighted_block_sum(
        &self,
        theta: &Matrix,
        w: &Matrix,
        n1: usize,
        n2: usize,
    ) -> Result<Matrix> {
        kron::weighted_block_sum(theta, w, n1, n2)
    }
    fn block_trace_into(
        &self,
        theta: &Matrix,
        l2: &Matrix,
        n1: usize,
        n2: usize,
        out: &mut Matrix,
    ) -> Result<()> {
        kron::block_trace_into(theta, l2, n1, n2, out)
    }
    fn weighted_block_sum_into(
        &self,
        theta: &Matrix,
        w: &Matrix,
        n1: usize,
        n2: usize,
        out: &mut Matrix,
    ) -> Result<()> {
        kron::weighted_block_sum_into(theta, w, n1, n2, out)
    }

    fn contract_compressed(
        &self,
        kernel: KernelRef<'_>,
        stats: &CompressedTraining,
        engine: &mut ThetaEngine,
        op: Contraction,
        out: &mut Matrix,
    ) -> Result<f64> {
        engine.contract(kernel, stats, op, out)
    }
}

/// Reusable workspaces of one KRK-Picard-style learner: eigendecomposition
/// scratches for both sub-kernels, GEMM pack buffers, contraction /
/// sandwich outputs, and the candidate + PD-check buffers of the step
/// safeguard. After the first step (which grows the buffers) every
/// half-update runs without touching the heap.
#[derive(Default)]
pub(crate) struct KrkScratch {
    pub(crate) e1: SymEigenScratch,
    pub(crate) e2: SymEigenScratch,
    /// Θ-contraction output (`A₁` or `A₂`).
    pub(crate) contr: Matrix,
    /// `L·A·L` sandwich output; becomes the step direction `X` in place.
    pub(crate) sand: Matrix,
    /// GEMM association temporary.
    pub(crate) tmp: Matrix,
    /// `L₁·B·L₁` / `B₂` output.
    pub(crate) bmat: Matrix,
    pub(crate) diag: Vec<f64>,
    /// Step candidate; after the swap in [`apply_step_into`] it holds the
    /// previous iterate — the rollback buffer of the next backtrack.
    pub(crate) candidate: Matrix,
    /// Cholesky factor buffer of the PD safeguard.
    pub(crate) cholwork: Matrix,
    pub(crate) gemm: GemmScratch,
}

/// The KRK-Picard learner (batch updates).
pub struct KrkPicard {
    pub(crate) l1: Matrix,
    pub(crate) l2: Matrix,
    /// Step size `a` (§3.1.1; 1.0 = guaranteed monotonic ascent).
    pub step_size: f64,
    /// PD-safeguard fallback for a > 1 (fall back to the `a = 1` step,
    /// which Prop. 3.1 guarantees PD, when the aggressive step leaves the
    /// PD cone).
    pub safeguard: bool,
    backend: Box<dyn Contractions>,
    scratch: KrkScratch,
    /// Θ-free sweep engine (per-stripe partials + factor scratch).
    engine: ThetaEngine,
    /// Compressed training statistics, rebuilt only when the data changes.
    cache: StatsCache,
    /// Objective at the iterate that entered the last [`Learner::step`] —
    /// fused out of that step's `A₁` sweep at zero extra factorizations.
    pre_step_ll: Option<f64>,
}

impl KrkPicard {
    /// Start from PD sub-kernels.
    pub fn new(l1: Matrix, l2: Matrix, step_size: f64) -> Result<Self> {
        Self::with_backend(l1, l2, step_size, Box::new(CpuContractions))
    }

    /// Start with a custom contraction backend (e.g. the PJRT runtime).
    pub fn with_backend(
        l1: Matrix,
        l2: Matrix,
        step_size: f64,
        backend: Box<dyn Contractions>,
    ) -> Result<Self> {
        if !l1.is_square() || !l2.is_square() {
            return Err(Error::Shape("krk: sub-kernels must be square".into()));
        }
        Ok(KrkPicard {
            l1,
            l2,
            step_size,
            safeguard: true,
            backend,
            scratch: KrkScratch::default(),
            engine: ThetaEngine::new(),
            cache: StatsCache::default(),
            pre_step_ll: None,
        })
    }

    /// Mean log-likelihood of the iterate that *entered* the most recent
    /// [`Learner::step`], fused out of that step's `A₁` sweep
    /// (`Σᵢ wᵢ·log det L_{Yᵢ}` from the shared factorization, normalizer
    /// from the sub-spectra already eigendecomposed for the `B`-half) — the
    /// free objective signal for backtracking and monitoring. `None` before
    /// the first step or when the training set was empty.
    pub fn pre_step_objective(&self) -> Option<f64> {
        self.pre_step_ll
    }

    /// Sub-kernel sizes `(N₁, N₂)`.
    pub fn dims(&self) -> (usize, usize) {
        (self.l1.rows(), self.l2.rows())
    }

    /// Borrow the current sub-kernels.
    pub fn subkernels(&self) -> (&Matrix, &Matrix) {
        (&self.l1, &self.l2)
    }

    /// One L₁ half-update given a Θ (dense). `O(N² + N₁³ + N₂³)`. Kept as
    /// the Θ-consuming API (runtime backends, oracle tests); the batch
    /// [`Learner::step`] goes through the Θ-free compressed path instead.
    ///
    /// Steady-state allocation-free: the contraction, the `L₁·A₁·L₁`
    /// sandwich, the eigen-space `L₁·B·L₁` term and the PD-safeguarded
    /// step all run in learner-held buffers (asserted by the counting-
    /// allocator suite in `tests/alloc_free.rs`).
    pub fn update_l1_from_theta(&mut self, theta: &Matrix) -> Result<()> {
        let (n1, n2) = self.dims();
        self.backend.block_trace_into(theta, &self.l2, n1, n2, &mut self.scratch.contr)?;
        self.apply_l1_direction()
    }

    /// One L₂ half-update given a Θ (dense). `O(N² + N₁³ + N₂³)`;
    /// steady-state allocation-free like [`KrkPicard::update_l1_from_theta`].
    pub fn update_l2_from_theta(&mut self, theta: &Matrix) -> Result<()> {
        let (n1, n2) = self.dims();
        self.backend.weighted_block_sum_into(theta, &self.l1, n1, n2, &mut self.scratch.contr)?;
        self.apply_l2_direction()
    }

    /// Finish the L₁ half-update from `scratch.contr` holding `A₁`:
    /// sandwich, eigen-space `B`-term, PD-safeguarded step.
    fn apply_l1_direction(&mut self) -> Result<()> {
        let (_, n2) = self.dims();
        let s = &mut self.scratch;
        matmul::sandwich_into(&mut s.sand, &self.l1, &s.contr, &self.l1, &mut s.tmp, &mut s.gemm)?;
        l1_b_l1_into(&self.l1, &self.l2, s)?;
        s.sand -= &s.bmat;
        apply_step_into(
            &mut self.l1,
            &s.sand,
            self.step_size / n2 as f64,
            1.0 / n2 as f64,
            self.safeguard,
            &mut s.candidate,
            &mut s.cholwork,
        );
        Ok(())
    }

    /// Finish the L₂ half-update from `scratch.contr` holding `A₂`.
    fn apply_l2_direction(&mut self) -> Result<()> {
        let (n1, _) = self.dims();
        let s = &mut self.scratch;
        matmul::sandwich_into(&mut s.sand, &self.l2, &s.contr, &self.l2, &mut s.tmp, &mut s.gemm)?;
        b2_matrix_into(&self.l1, &self.l2, s)?;
        s.sand -= &s.bmat;
        apply_step_into(
            &mut self.l2,
            &s.sand,
            self.step_size / n1 as f64,
            1.0 / n1 as f64,
            self.safeguard,
            &mut s.candidate,
            &mut s.cholwork,
        );
        Ok(())
    }
}

/// The in-place PD-safeguarded step: build the candidate in a learner-held
/// buffer, check PD in a reused Cholesky buffer, and *swap* the candidate
/// into place — after which `candidate` holds the previous iterate, i.e.
/// the rollback copy of the next step-size backtrack. No `clone()` per
/// backtrack.
pub(crate) fn apply_step_into(
    l: &mut Matrix,
    x: &Matrix,
    scaled: f64,
    unit: f64,
    safeguard: bool,
    candidate: &mut Matrix,
    cholwork: &mut Matrix,
) {
    candidate.copy_from(l);
    candidate.axpy(scaled, x).expect("shape-consistent by construction");
    candidate.symmetrize_mut();
    if safeguard
        && (scaled - unit).abs() > 1e-15
        && !crate::linalg::cholesky::is_pd_with(candidate, cholwork)
    {
        candidate.copy_from(l);
        candidate.axpy(unit, x).expect("shape-consistent by construction");
        candidate.symmetrize_mut();
    }
    std::mem::swap(l, candidate);
}

/// `L₁·B·L₁ = P₁·diag(d₁ₖ²·Qₖ)·P₁ᵀ` with `Qₖ = Σ_r d₂ᵣ/(1+d₁ₖd₂ᵣ)`
/// (App. B.1). `O(N₁³ + N₂³ + N₁N₂)`. Allocating wrapper, kept as the
/// test oracle of the m = 3 grouped B-halves.
#[cfg(test)]
pub(crate) fn l1_b_l1(l1: &Matrix, l2: &Matrix) -> Result<Matrix> {
    let mut s = KrkScratch::default();
    l1_b_l1_into(l1, l2, &mut s)?;
    Ok(std::mem::replace(&mut s.bmat, Matrix::zeros(0, 0)))
}

/// [`l1_b_l1`] into `s.bmat`, reusing the scratch's eigen workspaces,
/// diagonal buffer and GEMM pack buffers.
pub(crate) fn l1_b_l1_into(l1: &Matrix, l2: &Matrix, s: &mut KrkScratch) -> Result<()> {
    eigen::factor_into(l1, &mut s.e1)?;
    eigen::factor_into(l2, &mut s.e2)?;
    let n1 = l1.rows();
    s.diag.clear();
    s.diag.resize(n1, 0.0);
    for (k, dk) in s.diag.iter_mut().enumerate() {
        let d1k = s.e1.values[k];
        let q: f64 = s.e2.values.iter().map(|&d2r| d2r / (1.0 + d1k * d2r)).sum();
        *dk = d1k * d1k * q;
    }
    reconstruct_diag_into(&s.e1.vectors, &s.diag, &mut s.bmat, &mut s.tmp, &mut s.gemm);
    Ok(())
}

/// `B₂ = P₂·diag_r(Σ_k d₁ₖd₂ᵣ²/(1+d₁ₖd₂ᵣ))·P₂ᵀ` (App. B.2; the
/// `Σ_i P₁[i,k]²` factor is 1 by orthonormality). `O(N₁³+N₂³+N₁N₂)`.
/// Allocating wrapper, kept as the m = 3 grouped-B-half test oracle.
#[cfg(test)]
pub(crate) fn b2_matrix(l1: &Matrix, l2: &Matrix) -> Result<Matrix> {
    let mut s = KrkScratch::default();
    b2_matrix_into(l1, l2, &mut s)?;
    Ok(std::mem::replace(&mut s.bmat, Matrix::zeros(0, 0)))
}

/// [`b2_matrix`] into `s.bmat` (see [`l1_b_l1_into`]).
pub(crate) fn b2_matrix_into(l1: &Matrix, l2: &Matrix, s: &mut KrkScratch) -> Result<()> {
    eigen::factor_into(l1, &mut s.e1)?;
    eigen::factor_into(l2, &mut s.e2)?;
    let n2 = l2.rows();
    s.diag.clear();
    s.diag.resize(n2, 0.0);
    for (r, dr) in s.diag.iter_mut().enumerate() {
        let d2r = s.e2.values[r];
        let sum: f64 =
            s.e1.values.iter().map(|&d1k| d1k * d2r * d2r / (1.0 + d1k * d2r)).sum();
        *dr = sum;
    }
    reconstruct_diag_into(&s.e2.vectors, &s.diag, &mut s.bmat, &mut s.tmp, &mut s.gemm);
    Ok(())
}

/// `P·diag(d)·Pᵀ`.
pub(crate) fn reconstruct_diag(p: &Matrix, d: &[f64]) -> Matrix {
    let mut out = Matrix::zeros(0, 0);
    let mut tmp = Matrix::zeros(0, 0);
    let mut gemm = GemmScratch::new();
    reconstruct_diag_into(p, d, &mut out, &mut tmp, &mut gemm);
    out
}

/// `out = P·diag(d)·Pᵀ` in caller-held buffers: scale columns into `tmp`,
/// one view-GEMM against `Pᵀ` (a free transpose view), symmetrize.
pub(crate) fn reconstruct_diag_into(
    p: &Matrix,
    d: &[f64],
    out: &mut Matrix,
    tmp: &mut Matrix,
    gemm: &mut GemmScratch,
) {
    let n = p.rows();
    tmp.resize_zeroed(n, n);
    for i in 0..n {
        let prow = p.row(i);
        let trow = tmp.row_mut(i);
        for ((t, &pv), &dv) in trow.iter_mut().zip(prow).zip(d) {
            *t = pv * dv;
        }
    }
    out.resize_zeroed(n, n);
    matmul::gemm_into(out.view_mut(), 1.0, tmp.view(), p.view().t(), false, gemm);
    out.symmetrize_mut();
}

impl Learner for KrkPicard {
    fn name(&self) -> &'static str {
        "krk-picard"
    }

    fn step(&mut self, data: &TrainingSet) -> Result<()> {
        // Block-coordinate: each half-update uses the Θ-statistics of the
        // *current* kernel (Alg. 1 computes Δ fresh per line) — contracted
        // straight from the compressed subset inverses; no N×N Θ exists on
        // this path.
        let (n1, n2) = self.dims();
        let shape = KernelShape::Kron2 { n1, n2 };
        let data_term = {
            let stats = self.cache.get(&data.subsets, shape)?;
            self.backend.contract_compressed(
                KernelRef::Kron2(&self.l1, &self.l2),
                stats,
                &mut self.engine,
                Contraction::A1,
                &mut self.scratch.contr,
            )?
        };
        self.apply_l1_direction()?;
        // Fused objective: the A₁ sweep's Σ wᵢ·logdet L_{Yᵢ} minus the
        // normalizer from the sub-spectra the B-half just eigendecomposed
        // (still the pre-update kernel) — φ at the iterate entering this
        // step, at zero extra factorizations.
        self.pre_step_ll = if data.subsets.is_empty() {
            None
        } else {
            Some(
                data_term
                    - logdet_lpi_kron2(&self.scratch.e1.values, &self.scratch.e2.values)?,
            )
        };
        {
            let stats = self.cache.get(&data.subsets, shape)?;
            self.backend.contract_compressed(
                KernelRef::Kron2(&self.l1, &self.l2),
                stats,
                &mut self.engine,
                Contraction::A2,
                &mut self.scratch.contr,
            )?;
        }
        self.apply_l2_direction()?;
        Ok(())
    }

    fn objective(&mut self, data: &TrainingSet) -> Result<f64> {
        // Compressed-path objective: deduplicated, parallel, allocation-
        // free logdet sweep + sub-spectrum normalizer — same value as the
        // dense Eq.-3 evaluation, without re-factorizing duplicates.
        if data.subsets.is_empty() {
            return Ok(0.0);
        }
        let (n1, n2) = self.dims();
        let stats = self.cache.get(&data.subsets, KernelShape::Kron2 { n1, n2 })?;
        let data_term =
            self.engine.sum_logdet(KernelRef::Kron2(&self.l1, &self.l2), stats)?;
        eigen::factor_into(&self.l1, &mut self.scratch.e1)?;
        eigen::factor_into(&self.l2, &mut self.scratch.e2)?;
        Ok(data_term - logdet_lpi_kron2(&self.scratch.e1.values, &self.scratch.e2.values)?)
    }

    fn kernel(&self) -> Kernel {
        Kernel::Kron2(self.l1.clone(), self.l2.clone())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dpp::likelihood::{log_likelihood, theta_dense};
    use crate::dpp::Sampler;
    use crate::linalg::cholesky;
    use crate::rng::Rng;

    fn sub_kernel(n: usize, rng: &mut Rng) -> Matrix {
        let mut l = rng.paper_init_kernel(n);
        l.scale_mut(1.5 / n as f64);
        l.add_diag_mut(0.3);
        l
    }

    fn setup(n1: usize, n2: usize, count: usize, seed: u64) -> (TrainingSet, KrkPicard) {
        let mut rng = Rng::new(seed);
        let true_kernel = Kernel::Kron2(sub_kernel(n1, &mut rng), sub_kernel(n2, &mut rng));
        let sampler = Sampler::new(&true_kernel).unwrap();
        let subsets: Vec<Vec<usize>> =
            (0..count).map(|_| sampler.sample(&mut rng)).collect();
        let data = TrainingSet::new(n1 * n2, subsets).unwrap();
        let learner =
            KrkPicard::new(sub_kernel(n1, &mut rng), sub_kernel(n2, &mut rng), 1.0).unwrap();
        (data, learner)
    }

    /// Reference implementation of the L1 update, straight from Prop. 3.1:
    /// L1 ← L1 + a·Tr1((I⊗L2⁻¹)(LΔL))/N2, everything dense.
    fn reference_updates(
        l1: &Matrix,
        l2: &Matrix,
        data: &TrainingSet,
        a: f64,
    ) -> (Matrix, Matrix) {
        let n1 = l1.rows();
        let n2 = l2.rows();
        let kernel = Kernel::Kron2(l1.clone(), l2.clone());
        let l = kernel.to_dense();
        let theta = theta_dense(&kernel, &data.subsets).unwrap();
        let mut l_plus_i = l.clone();
        l_plus_i.add_diag_mut(1.0);
        let inv = cholesky::inverse_pd(&l_plus_i).unwrap();
        let mut delta = theta;
        delta -= &inv;
        let ldl = matmul::sandwich(&l, &delta, &l).unwrap();
        // L1 update
        let s2 = cholesky::inverse_pd(l2).unwrap();
        let tr1 = kron::tr1_scaled(&ldl, &s2, n1, n2).unwrap();
        let mut new_l1 = l1.clone();
        new_l1.axpy(a / n2 as f64, &tr1).unwrap();
        // L2 update (using the NEW l1, as in the block-coordinate Alg. 1)
        let kernel_mid = Kernel::Kron2(new_l1.clone(), l2.clone());
        let l_mid = kernel_mid.to_dense();
        let theta_mid = theta_dense(&kernel_mid, &data.subsets).unwrap();
        let mut l_plus_i = l_mid.clone();
        l_plus_i.add_diag_mut(1.0);
        let inv = cholesky::inverse_pd(&l_plus_i).unwrap();
        let mut delta = theta_mid;
        delta -= &inv;
        let ldl = matmul::sandwich(&l_mid, &delta, &l_mid).unwrap();
        let s1 = cholesky::inverse_pd(&new_l1).unwrap();
        let tr2 = kron::tr2_scaled(&ldl, &s1, n1, n2).unwrap();
        let mut new_l2 = l2.clone();
        new_l2.axpy(a / n1 as f64, &tr2).unwrap();
        (new_l1, new_l2)
    }

    #[test]
    fn efficient_update_matches_definition() {
        // The App.-B fast path must agree with the dense Prop.-3.1 formula.
        let (data, mut learner) = setup(3, 4, 25, 42);
        let (l1_0, l2_0) = (learner.l1.clone(), learner.l2.clone());
        let (ref_l1, ref_l2) = reference_updates(&l1_0, &l2_0, &data, 1.0);
        learner.step(&data).unwrap();
        assert!(
            learner.l1.rel_diff(&ref_l1) < 1e-9,
            "L1 mismatch: {}",
            learner.l1.rel_diff(&ref_l1)
        );
        assert!(
            learner.l2.rel_diff(&ref_l2) < 1e-9,
            "L2 mismatch: {}",
            learner.l2.rel_diff(&ref_l2)
        );
    }

    #[test]
    fn monotonic_ascent_unit_step() {
        // Thm. 3.2: likelihood non-decreasing for a = 1.
        let (data, mut learner) = setup(3, 4, 30, 7);
        let result = learner.run(&data, 20, 0.0).unwrap();
        for w in result.history.windows(2) {
            assert!(
                w[1].log_likelihood >= w[0].log_likelihood - 1e-9,
                "descent at iter {}: {} -> {}",
                w[1].iter,
                w[0].log_likelihood,
                w[1].log_likelihood
            );
        }
    }

    #[test]
    fn iterates_stay_pd() {
        // Prop. 3.1: updates are positive definite.
        let (data, mut learner) = setup(4, 3, 30, 11);
        for _ in 0..15 {
            learner.step(&data).unwrap();
            assert!(cholesky::is_pd(&learner.l1), "L1 lost PD");
            assert!(cholesky::is_pd(&learner.l2), "L2 lost PD");
        }
    }

    #[test]
    fn improves_likelihood_substantially() {
        let (data, mut learner) = setup(4, 4, 60, 13);
        let ll0 = log_likelihood(&learner.kernel(), &data.subsets).unwrap();
        let result = learner.run(&data, 25, 0.0).unwrap();
        assert!(result.final_ll() > ll0 + 0.1, "{} -> {}", ll0, result.final_ll());
    }

    #[test]
    fn rectangular_subkernel_sizes() {
        // N1 ≠ N2 exercises every transpose/index path.
        let (data, mut learner) = setup(2, 6, 25, 17);
        let result = learner.run(&data, 8, 0.0).unwrap();
        for w in result.history.windows(2) {
            assert!(w[1].log_likelihood >= w[0].log_likelihood - 1e-9);
        }
    }

    #[test]
    fn larger_step_moves_faster_initially() {
        // §3.1.1: a > 1 can speed early progress (not guaranteed; checked
        // on a seed where it holds, as an executable documentation of the
        // step-size generalization).
        let (data, mut fast) = setup(3, 3, 40, 19);
        let (_, mut slow) = setup(3, 3, 40, 19);
        fast.step_size = 1.5;
        slow.step_size = 1.0;
        let rf = fast.run(&data, 1, 0.0).unwrap();
        let rs = slow.run(&data, 1, 0.0).unwrap();
        assert!(rf.first_iter_gain() > rs.first_iter_gain());
    }
}
