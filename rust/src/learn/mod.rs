//! Kernel-learning algorithms: the paper's KRK-Picard (batch + stochastic),
//! plus every baseline its evaluation compares against.
//!
//! - [`krk`]: KRK-Picard, Algorithm 1 (the paper's contribution).
//! - [`krk_stochastic`]: stochastic/minibatch variant (Thm. 3.3 2nd half).
//! - [`picard`]: the full Picard iteration baseline (ref. [25]).
//! - [`joint`]: Joint-Picard, Algorithm 3 (§3.2 / App. C).
//! - [`em`]: the EM baseline (ref. [10], Table-1 comparison).
//! - [`clustering`]: greedy SUKP subset clustering (§3.3).
//! - [`init`]: the paper's §5 initialization protocols.
//! - [`stats`]: compressed training statistics — the Θ-free `O(nκ²)`
//!   gradient-contraction engine every batch learner routes through.
//! - [`traits`]: the shared `Learner` interface and training-set types.

pub mod clustering;
pub mod em;
pub mod init;
pub mod joint;
pub mod krk;
pub mod krk3;
pub mod krk_stochastic;
pub mod lowrank;
pub mod picard;
pub mod stats;
pub mod traits;

pub use em::EmLearner;
pub use joint::JointPicard;
pub use krk::KrkPicard;
pub use krk3::Krk3Picard;
pub use krk_stochastic::KrkStochastic;
pub use lowrank::LowRank;
pub use picard::Picard;
pub use stats::{CompressedTraining, ThetaEngine};
pub use traits::{IterRecord, Learner, LearnResult, TrainingSet};
