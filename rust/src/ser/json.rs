//! Minimal JSON parser + writer (serde is not available offline).
//!
//! Used for the artifact manifest written by `python/compile/aot.py`, for
//! experiment configuration files, and for structured results emitted by
//! the figure harness. Supports the full JSON grammar except `\u` surrogate
//! pairs beyond the BMP (sufficient for our ASCII manifests).

use crate::error::{Error, Result};
use std::collections::BTreeMap;
use std::fmt::Write as _;

/// A JSON value.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    /// Parse a JSON document.
    pub fn parse(text: &str) -> Result<Json> {
        let mut p = Parser { bytes: text.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(Error::Parse(format!("json: trailing data at byte {}", p.pos)));
        }
        Ok(v)
    }

    /// Serialize compactly.
    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => {
                if x.fract() == 0.0 && x.abs() < 1e15 {
                    let _ = write!(out, "{}", *x as i64);
                } else {
                    let _ = write!(out, "{x}");
                }
            }
            Json::Str(s) => write_escaped(s, out),
            Json::Arr(items) => {
                out.push('[');
                for (i, v) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write(out);
                }
                out.push(']');
            }
            Json::Obj(map) => {
                out.push('{');
                for (i, (k, v)) in map.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }

    /// Accessors returning typed values (errors carry the expected type).
    pub fn as_str(&self) -> Result<&str> {
        match self {
            Json::Str(s) => Ok(s),
            _ => Err(Error::Parse(format!("json: expected string, got {self:?}"))),
        }
    }

    pub fn as_f64(&self) -> Result<f64> {
        match self {
            Json::Num(x) => Ok(*x),
            _ => Err(Error::Parse(format!("json: expected number, got {self:?}"))),
        }
    }

    pub fn as_usize(&self) -> Result<usize> {
        let x = self.as_f64()?;
        if x < 0.0 || x.fract() != 0.0 {
            return Err(Error::Parse(format!("json: expected non-negative integer, got {x}")));
        }
        Ok(x as usize)
    }

    pub fn as_bool(&self) -> Result<bool> {
        match self {
            Json::Bool(b) => Ok(*b),
            _ => Err(Error::Parse(format!("json: expected bool, got {self:?}"))),
        }
    }

    pub fn as_arr(&self) -> Result<&[Json]> {
        match self {
            Json::Arr(v) => Ok(v),
            _ => Err(Error::Parse(format!("json: expected array, got {self:?}"))),
        }
    }

    pub fn as_obj(&self) -> Result<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Ok(m),
            _ => Err(Error::Parse(format!("json: expected object, got {self:?}"))),
        }
    }

    /// Object field lookup with a descriptive error.
    pub fn get(&self, key: &str) -> Result<&Json> {
        self.as_obj()?
            .get(key)
            .ok_or_else(|| Error::Parse(format!("json: missing field '{key}'")))
    }

    /// Optional field lookup.
    pub fn get_opt(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// Build an object from pairs.
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }
}

fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.pos < self.bytes.len()
            && matches!(self.bytes[self.pos], b' ' | b'\t' | b'\n' | b'\r')
        {
            self.pos += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn expect(&mut self, b: u8) -> Result<()> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(Error::Parse(format!(
                "json: expected '{}' at byte {}, found {:?}",
                b as char,
                self.pos,
                self.peek().map(|c| c as char)
            )))
        }
    }

    fn value(&mut self) -> Result<Json> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            other => Err(Error::Parse(format!(
                "json: unexpected {:?} at byte {}",
                other.map(|c| c as char),
                self.pos
            ))),
        }
    }

    fn literal(&mut self, word: &str, v: Json) -> Result<Json> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            Err(Error::Parse(format!("json: invalid literal at byte {}", self.pos)))
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos])
            .map_err(|_| Error::Parse("json: bad number bytes".into()))?;
        let x = text
            .parse::<f64>()
            .map_err(|_| Error::Parse(format!("json: bad number '{text}'")))?;
        // Overflowing literals ("1e999") parse to ±inf; reject them here so
        // poison can never enter a kernel matrix through a config file.
        if !x.is_finite() {
            return Err(Error::Parse(format!(
                "json: non-finite number '{text}' at byte {start}"
            )));
        }
        Ok(Json::Num(x))
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err(Error::Parse("json: unterminated string".into())),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'n') => out.push('\n'),
                        Some(b't') => out.push('\t'),
                        Some(b'r') => out.push('\r'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'u') => {
                            if self.pos + 4 >= self.bytes.len() {
                                return Err(Error::Parse("json: truncated \\u escape".into()));
                            }
                            let hex =
                                std::str::from_utf8(&self.bytes[self.pos + 1..self.pos + 5])
                                    .map_err(|_| Error::Parse("json: bad \\u escape".into()))?;
                            let code = u32::from_str_radix(hex, 16)
                                .map_err(|_| Error::Parse("json: bad \\u escape".into()))?;
                            out.push(
                                char::from_u32(code)
                                    .ok_or_else(|| Error::Parse("json: bad codepoint".into()))?,
                            );
                            self.pos += 4;
                        }
                        other => {
                            return Err(Error::Parse(format!(
                                "json: bad escape {:?}",
                                other.map(|c| c as char)
                            )))
                        }
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // Copy a full UTF-8 scalar.
                    let rest = std::str::from_utf8(&self.bytes[self.pos..])
                        .map_err(|_| Error::Parse("json: invalid utf8".into()))?;
                    let c = rest.chars().next().unwrap();
                    out.push(c);
                    self.pos += c.len_utf8();
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(items));
                }
                _ => return Err(Error::Parse(format!("json: bad array at byte {}", self.pos))),
            }
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(map));
                }
                _ => return Err(Error::Parse(format!("json: bad object at byte {}", self.pos))),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_scalars() {
        for text in ["null", "true", "false", "0", "-1.5", "1e3", "\"hi\""] {
            let v = Json::parse(text).unwrap();
            let again = Json::parse(&v.to_string()).unwrap();
            assert_eq!(v, again, "{text}");
        }
    }

    #[test]
    fn parse_nested() {
        let text = r#"{"name": "krk", "sizes": [100, 100], "nested": {"a": [1, {"b": null}]}, "ok": true}"#;
        let v = Json::parse(text).unwrap();
        assert_eq!(v.get("name").unwrap().as_str().unwrap(), "krk");
        assert_eq!(v.get("sizes").unwrap().as_arr().unwrap().len(), 2);
        assert_eq!(v.get("sizes").unwrap().as_arr().unwrap()[0].as_usize().unwrap(), 100);
        assert!(v.get("ok").unwrap().as_bool().unwrap());
    }

    #[test]
    fn escapes_roundtrip() {
        let v = Json::Str("a\"b\\c\nd\tµ".to_string());
        let parsed = Json::parse(&v.to_string()).unwrap();
        assert_eq!(v, parsed);
    }

    #[test]
    fn unicode_escape() {
        let v = Json::parse(r#""µ""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "µ");
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("nulll").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse(r#"{"a" 1}"#).is_err());
    }

    #[test]
    fn missing_field_error_names_field() {
        let v = Json::parse("{}").unwrap();
        let err = v.get("kernel").unwrap_err();
        assert!(err.to_string().contains("kernel"));
    }

    #[test]
    fn numbers_exact_for_integers() {
        let v = Json::Num(144.0);
        assert_eq!(v.to_string(), "144");
        let v = Json::Num(1.25);
        assert_eq!(v.to_string(), "1.25");
    }

    #[test]
    fn rejects_non_finite_number_literals() {
        // Overflowing exponents would otherwise smuggle ±inf into kernels.
        for text in ["1e999", "-1e999", "[1.0, 2.0, 1e400]"] {
            let err = Json::parse(text).unwrap_err();
            assert!(
                err.to_string().contains("non-finite"),
                "{text} gave: {err}"
            );
        }
        // Large-but-finite numbers still parse.
        assert!(Json::parse("1e308").is_ok());
    }
}
