//! Length-prefixed JSON wire protocol for the TCP serving layer
//! (DESIGN.md §3.2).
//!
//! Every frame is a 4-byte **big-endian u32 length prefix** followed by
//! exactly that many bytes of UTF-8 JSON. Frames larger than the
//! negotiated cap ([`DEFAULT_MAX_FRAME`] by default) are a protocol
//! error: the reader reports it *before* buffering the payload so a
//! hostile peer cannot balloon memory, and the connection layer closes
//! the socket. Everything below the frame boundary — garbage JSON,
//! missing fields, unknown ops — is a *payload* error: the server
//! answers with an error envelope and the connection stays open.
//!
//! Request envelope (`op` selects the variant):
//!
//! ```json
//! {"id": 7, "op": "sample", "tenant": "news", "k": 5,
//!  "mode": {"name": "mcmc", "steps": 4000},
//!  "include": [1], "exclude": [4, 9], "budget_ms": 50}
//! ```
//!
//! `mode` is either a bare string (`"exact"`, `"map"`) or an object with
//! `name` + backend parameters; `op: "map"` is sugar for a sample request
//! pinned to the MAP backend. Responses are `{"id": N, "ok": {...}}` or
//! `{"id": N, "err": {"kind": ..., "retryable": ..., "message": ...}}`,
//! where `kind` is the [`ErrorKind::label`] taxonomy so clients can
//! reconstruct a typed [`Error`] and honor [`Error::is_retryable`].

use crate::dpp::{KernelDelta, SampleMode};
use crate::error::{Error, Result};
use crate::linalg::Matrix;
use crate::ser::json::Json;

/// Default cap on a single frame's payload: 1 MiB.
pub const DEFAULT_MAX_FRAME: usize = 1 << 20;

/// Size of the length prefix in bytes.
pub const LEN_PREFIX_BYTES: usize = 4;

/// Wrap a payload in a length-prefixed frame. Rejects payloads larger
/// than `max_frame` (the peer would drop the connection anyway).
pub fn encode_frame(payload: &[u8], max_frame: usize) -> Result<Vec<u8>> {
    if payload.len() > max_frame {
        return Err(Error::Invalid(format!(
            "frame payload {} bytes exceeds cap {}",
            payload.len(),
            max_frame
        )));
    }
    if payload.len() > u32::MAX as usize {
        return Err(Error::Invalid(format!(
            "frame payload {} bytes exceeds u32 length prefix",
            payload.len()
        )));
    }
    let mut out = Vec::with_capacity(LEN_PREFIX_BYTES + payload.len());
    out.extend_from_slice(&(payload.len() as u32).to_be_bytes());
    out.extend_from_slice(payload);
    Ok(out)
}

/// Incremental frame decoder: feed raw socket bytes with [`push`],
/// drain complete payloads with [`next`]. A declared length above the
/// cap is a hard protocol error — the caller must close the connection.
///
/// [`push`]: FrameReader::push
/// [`next`]: FrameReader::next
#[derive(Debug)]
pub struct FrameReader {
    buf: Vec<u8>,
    max_frame: usize,
}

impl FrameReader {
    /// Reader with the given per-frame payload cap.
    pub fn new(max_frame: usize) -> Self {
        FrameReader { buf: Vec::new(), max_frame }
    }

    /// Append raw bytes read off the socket.
    pub fn push(&mut self, bytes: &[u8]) {
        self.buf.extend_from_slice(bytes);
    }

    /// Bytes currently buffered (prefix + partial payloads).
    pub fn buffered(&self) -> usize {
        self.buf.len()
    }

    /// Next complete payload, `Ok(None)` if more bytes are needed, or a
    /// protocol error if the declared length exceeds the cap.
    pub fn next(&mut self) -> Result<Option<Vec<u8>>> {
        if self.buf.len() < LEN_PREFIX_BYTES {
            return Ok(None);
        }
        let len = u32::from_be_bytes([self.buf[0], self.buf[1], self.buf[2], self.buf[3]]) as usize;
        if len > self.max_frame {
            return Err(Error::Parse(format!(
                "declared frame length {} exceeds cap {}",
                len, self.max_frame
            )));
        }
        if self.buf.len() < LEN_PREFIX_BYTES + len {
            return Ok(None);
        }
        let payload = self.buf[LEN_PREFIX_BYTES..LEN_PREFIX_BYTES + len].to_vec();
        self.buf.drain(..LEN_PREFIX_BYTES + len);
        Ok(Some(payload))
    }
}

/// A decoded client request. `id` is an opaque client-chosen correlation
/// token echoed verbatim in the response.
#[derive(Clone, Debug)]
pub enum WireRequest {
    /// Draw a slate: `op: "sample"` (or `"map"`, which pins the mode).
    Sample {
        id: u64,
        tenant: String,
        k: usize,
        mode: SampleMode,
        include: Vec<usize>,
        exclude: Vec<usize>,
        budget_ms: Option<u64>,
    },
    /// Per-item inclusion marginals: `op: "marginals"`.
    Marginals { id: u64, tenant: String },
    /// Stream a catalog delta into the tenant's kernel: `op: "publish_delta"`.
    PublishDelta { id: u64, tenant: String, delta: KernelDelta },
    /// Render the service metrics report: `op: "report"`.
    Report { id: u64 },
    /// Begin graceful shutdown and drain: `op: "shutdown"`.
    Shutdown { id: u64 },
}

impl WireRequest {
    /// The client correlation id.
    pub fn id(&self) -> u64 {
        match self {
            WireRequest::Sample { id, .. }
            | WireRequest::Marginals { id, .. }
            | WireRequest::PublishDelta { id, .. }
            | WireRequest::Report { id }
            | WireRequest::Shutdown { id } => *id,
        }
    }

    /// Encode as a JSON envelope.
    pub fn encode(&self) -> Json {
        match self {
            WireRequest::Sample { id, tenant, k, mode, include, exclude, budget_ms } => {
                let mut pairs = vec![
                    ("id", Json::Num(*id as f64)),
                    ("op", Json::Str("sample".into())),
                    ("tenant", Json::Str(tenant.clone())),
                    ("k", Json::Num(*k as f64)),
                    ("mode", encode_mode(mode)),
                ];
                if !include.is_empty() {
                    pairs.push(("include", usize_arr_to_json(include)));
                }
                if !exclude.is_empty() {
                    pairs.push(("exclude", usize_arr_to_json(exclude)));
                }
                if let Some(ms) = budget_ms {
                    pairs.push(("budget_ms", Json::Num(*ms as f64)));
                }
                Json::obj(pairs)
            }
            WireRequest::Marginals { id, tenant } => Json::obj(vec![
                ("id", Json::Num(*id as f64)),
                ("op", Json::Str("marginals".into())),
                ("tenant", Json::Str(tenant.clone())),
            ]),
            WireRequest::PublishDelta { id, tenant, delta } => Json::obj(vec![
                ("id", Json::Num(*id as f64)),
                ("op", Json::Str("publish_delta".into())),
                ("tenant", Json::Str(tenant.clone())),
                ("delta", encode_delta(delta)),
            ]),
            WireRequest::Report { id } => Json::obj(vec![
                ("id", Json::Num(*id as f64)),
                ("op", Json::Str("report".into())),
            ]),
            WireRequest::Shutdown { id } => Json::obj(vec![
                ("id", Json::Num(*id as f64)),
                ("op", Json::Str("shutdown".into())),
            ]),
        }
    }

    /// Encode straight to a length-prefixed frame.
    pub fn to_frame(&self, max_frame: usize) -> Result<Vec<u8>> {
        encode_frame(self.encode().to_string().as_bytes(), max_frame)
    }

    /// Decode a frame payload: UTF-8 → JSON → envelope.
    pub fn from_payload(bytes: &[u8]) -> Result<WireRequest> {
        let text = std::str::from_utf8(bytes)
            .map_err(|_| Error::Parse("frame payload is not UTF-8".into()))?;
        WireRequest::decode(&Json::parse(text)?)
    }

    /// Decode from a parsed JSON envelope.
    pub fn decode(j: &Json) -> Result<WireRequest> {
        let id = j.get("id")?.as_usize()? as u64;
        let op = j.get("op")?.as_str()?.to_string();
        match op.as_str() {
            "sample" | "map" => {
                let tenant = j.get("tenant")?.as_str()?.to_string();
                let k = j.get("k")?.as_usize()?;
                let mode = if op == "map" {
                    SampleMode::Map
                } else {
                    match j.get_opt("mode") {
                        Some(m) => decode_mode(m)?,
                        None => SampleMode::Exact,
                    }
                };
                let include = match j.get_opt("include") {
                    Some(a) => json_to_usize_arr(a, "include")?,
                    None => Vec::new(),
                };
                let exclude = match j.get_opt("exclude") {
                    Some(a) => json_to_usize_arr(a, "exclude")?,
                    None => Vec::new(),
                };
                let budget_ms = match j.get_opt("budget_ms") {
                    Some(b) => Some(b.as_usize()? as u64),
                    None => None,
                };
                Ok(WireRequest::Sample { id, tenant, k, mode, include, exclude, budget_ms })
            }
            "marginals" => Ok(WireRequest::Marginals {
                id,
                tenant: j.get("tenant")?.as_str()?.to_string(),
            }),
            "publish_delta" => Ok(WireRequest::PublishDelta {
                id,
                tenant: j.get("tenant")?.as_str()?.to_string(),
                delta: decode_delta(j.get("delta")?)?,
            }),
            "report" => Ok(WireRequest::Report { id }),
            "shutdown" => Ok(WireRequest::Shutdown { id }),
            other => Err(Error::Parse(format!("unknown op '{other}'"))),
        }
    }
}

/// A server response envelope. Echoes the request `id`.
#[derive(Clone, Debug)]
pub enum WireResponse {
    /// Sampled (or MAP) slate.
    Items { id: u64, items: Vec<usize> },
    /// Per-item inclusion marginals.
    Marginals { id: u64, marginals: Vec<f64> },
    /// Delta publish outcome (mirrors [`crate::coordinator::DeltaOutcome`]).
    Delta { id: u64, generation: u64, incremental: bool, depth: u64 },
    /// Rendered metrics report.
    Report { id: u64, report: String },
    /// Shutdown acknowledged; the connection will drain and close.
    ShuttingDown { id: u64 },
    /// Typed failure: `kind` is the [`crate::error::ErrorKind::label`]
    /// taxonomy, `retryable` mirrors [`Error::is_retryable`].
    Failure { id: u64, kind: String, retryable: bool, message: String },
}

impl WireResponse {
    /// The echoed correlation id.
    pub fn id(&self) -> u64 {
        match self {
            WireResponse::Items { id, .. }
            | WireResponse::Marginals { id, .. }
            | WireResponse::Delta { id, .. }
            | WireResponse::Report { id, .. }
            | WireResponse::ShuttingDown { id }
            | WireResponse::Failure { id, .. } => *id,
        }
    }

    /// Build the failure envelope for a typed service error.
    pub fn from_error(id: u64, err: &Error) -> WireResponse {
        WireResponse::Failure {
            id,
            kind: err.kind().label().to_string(),
            retryable: err.is_retryable(),
            message: err.to_string(),
        }
    }

    /// Encode as a JSON envelope.
    pub fn encode(&self) -> Json {
        match self {
            WireResponse::Items { id, items } => ok_envelope(
                *id,
                Json::obj(vec![("items", usize_arr_to_json(items))]),
            ),
            WireResponse::Marginals { id, marginals } => ok_envelope(
                *id,
                Json::obj(vec![(
                    "marginals",
                    Json::Arr(marginals.iter().map(|&m| Json::Num(m)).collect()),
                )]),
            ),
            WireResponse::Delta { id, generation, incremental, depth } => ok_envelope(
                *id,
                Json::obj(vec![
                    ("generation", Json::Num(*generation as f64)),
                    ("incremental", Json::Bool(*incremental)),
                    ("depth", Json::Num(*depth as f64)),
                ]),
            ),
            WireResponse::Report { id, report } => ok_envelope(
                *id,
                Json::obj(vec![("report", Json::Str(report.clone()))]),
            ),
            WireResponse::ShuttingDown { id } => ok_envelope(
                *id,
                Json::obj(vec![("shutting_down", Json::Bool(true))]),
            ),
            WireResponse::Failure { id, kind, retryable, message } => Json::obj(vec![
                ("id", Json::Num(*id as f64)),
                (
                    "err",
                    Json::obj(vec![
                        ("kind", Json::Str(kind.clone())),
                        ("retryable", Json::Bool(*retryable)),
                        ("message", Json::Str(message.clone())),
                    ]),
                ),
            ]),
        }
    }

    /// Encode straight to a length-prefixed frame.
    pub fn to_frame(&self, max_frame: usize) -> Result<Vec<u8>> {
        encode_frame(self.encode().to_string().as_bytes(), max_frame)
    }

    /// Decode a frame payload: UTF-8 → JSON → envelope.
    pub fn from_payload(bytes: &[u8]) -> Result<WireResponse> {
        let text = std::str::from_utf8(bytes)
            .map_err(|_| Error::Parse("frame payload is not UTF-8".into()))?;
        WireResponse::decode(&Json::parse(text)?)
    }

    /// Decode from a parsed JSON envelope.
    pub fn decode(j: &Json) -> Result<WireResponse> {
        let id = j.get("id")?.as_usize()? as u64;
        if let Some(err) = j.get_opt("err") {
            return Ok(WireResponse::Failure {
                id,
                kind: err.get("kind")?.as_str()?.to_string(),
                retryable: err.get("retryable")?.as_bool()?,
                message: err.get("message")?.as_str()?.to_string(),
            });
        }
        let ok = j.get("ok")?;
        if let Some(items) = ok.get_opt("items") {
            return Ok(WireResponse::Items { id, items: json_to_usize_arr(items, "items")? });
        }
        if let Some(m) = ok.get_opt("marginals") {
            let marginals = m
                .as_arr()?
                .iter()
                .map(|v| v.as_f64())
                .collect::<Result<Vec<f64>>>()?;
            return Ok(WireResponse::Marginals { id, marginals });
        }
        if ok.get_opt("generation").is_some() {
            return Ok(WireResponse::Delta {
                id,
                generation: ok.get("generation")?.as_usize()? as u64,
                incremental: ok.get("incremental")?.as_bool()?,
                depth: ok.get("depth")?.as_usize()? as u64,
            });
        }
        if let Some(r) = ok.get_opt("report") {
            return Ok(WireResponse::Report { id, report: r.as_str()?.to_string() });
        }
        if ok.get_opt("shutting_down").is_some() {
            return Ok(WireResponse::ShuttingDown { id });
        }
        Err(Error::Parse("unrecognized ok payload".into()))
    }

    /// Client-side: collapse into a typed `Result` for slate responses.
    /// Failure envelopes reconstruct an [`Error`] of the original kind
    /// (same [`Error::is_retryable`]); non-slate payloads are a protocol
    /// error.
    pub fn into_items(self) -> Result<Vec<usize>> {
        match self {
            WireResponse::Items { items, .. } => Ok(items),
            WireResponse::Failure { kind, message, .. } => Err(decode_error(&kind, &message)),
            other => Err(Error::Parse(format!(
                "expected a slate response, got {other:?}"
            ))),
        }
    }
}

/// Reconstruct a typed [`Error`] from a wire `(kind, message)` pair.
/// Unknown kinds (a newer peer) degrade to [`Error::Service`], which is
/// retryable-false-safe for clients.
pub fn decode_error(kind: &str, message: &str) -> Error {
    let m = message.to_string();
    match kind {
        "shape" => Error::Shape(m),
        "numerical" => Error::Numerical(m),
        "invalid" => Error::Invalid(m),
        "io" => Error::Io(std::io::Error::new(std::io::ErrorKind::Other, m)),
        "parse" => Error::Parse(m),
        "runtime" => Error::Runtime(m),
        "service" => Error::Service(m),
        "rejected" => Error::Rejected(m),
        "deadline" => Error::Deadline(m),
        "throttled" => Error::Throttled(m),
        _ => Error::Service(m),
    }
}

fn ok_envelope(id: u64, body: Json) -> Json {
    Json::obj(vec![("id", Json::Num(id as f64)), ("ok", body)])
}

fn encode_mode(mode: &SampleMode) -> Json {
    match mode {
        SampleMode::Exact => Json::Str("exact".into()),
        SampleMode::Map => Json::Str("map".into()),
        SampleMode::Mcmc { steps } => Json::obj(vec![
            ("name", Json::Str("mcmc".into())),
            ("steps", Json::Num(*steps as f64)),
        ]),
        SampleMode::LowRank { rank } => Json::obj(vec![
            ("name", Json::Str("lowrank".into())),
            ("rank", Json::Num(*rank as f64)),
        ]),
    }
}

fn decode_mode(j: &Json) -> Result<SampleMode> {
    if let Ok(name) = j.as_str() {
        return SampleMode::parse(name, None, None);
    }
    let name = j.get("name")?.as_str()?.to_string();
    let steps = match j.get_opt("steps") {
        Some(s) => Some(s.as_usize()?),
        None => None,
    };
    let rank = match j.get_opt("rank") {
        Some(r) => Some(r.as_usize()?),
        None => None,
    };
    SampleMode::parse(&name, steps, rank)
}

fn encode_delta(delta: &KernelDelta) -> Json {
    match delta {
        KernelDelta::AddItem { side, row, diag } => Json::obj(vec![
            ("kind", Json::Str("add_item".into())),
            ("side", Json::Num(*side as f64)),
            ("row", Json::Arr(row.iter().map(|&v| Json::Num(v)).collect())),
            ("diag", Json::Num(*diag)),
        ]),
        KernelDelta::RemoveItem { side, index } => Json::obj(vec![
            ("kind", Json::Str("remove_item".into())),
            ("side", Json::Num(*side as f64)),
            ("index", Json::Num(*index as f64)),
        ]),
        KernelDelta::RetireItem { side, index, damping } => Json::obj(vec![
            ("kind", Json::Str("retire_item".into())),
            ("side", Json::Num(*side as f64)),
            ("index", Json::Num(*index as f64)),
            ("damping", Json::Num(*damping)),
        ]),
        KernelDelta::Perturb { side, rhos, vectors } => Json::obj(vec![
            ("kind", Json::Str("perturb".into())),
            ("side", Json::Num(*side as f64)),
            ("rhos", Json::Arr(rhos.iter().map(|&v| Json::Num(v)).collect())),
            (
                "vectors",
                Json::obj(vec![
                    ("rows", Json::Num(vectors.rows() as f64)),
                    ("cols", Json::Num(vectors.cols() as f64)),
                    (
                        "data",
                        Json::Arr(
                            (0..vectors.rows())
                                .flat_map(|i| (0..vectors.cols()).map(move |j| (i, j)))
                                .map(|(i, j)| Json::Num(vectors.get(i, j)))
                                .collect(),
                        ),
                    ),
                ]),
            ),
        ]),
    }
}

fn decode_delta(j: &Json) -> Result<KernelDelta> {
    let kind = j.get("kind")?.as_str()?.to_string();
    let side = j.get("side")?.as_usize()?;
    match kind.as_str() {
        "add_item" => Ok(KernelDelta::AddItem {
            side,
            row: j
                .get("row")?
                .as_arr()?
                .iter()
                .map(|v| v.as_f64())
                .collect::<Result<Vec<f64>>>()?,
            diag: j.get("diag")?.as_f64()?,
        }),
        "remove_item" => Ok(KernelDelta::RemoveItem { side, index: j.get("index")?.as_usize()? }),
        "retire_item" => Ok(KernelDelta::RetireItem {
            side,
            index: j.get("index")?.as_usize()?,
            damping: j.get("damping")?.as_f64()?,
        }),
        "perturb" => {
            let rhos = j
                .get("rhos")?
                .as_arr()?
                .iter()
                .map(|v| v.as_f64())
                .collect::<Result<Vec<f64>>>()?;
            let v = j.get("vectors")?;
            let rows = v.get("rows")?.as_usize()?;
            let cols = v.get("cols")?.as_usize()?;
            let data = v
                .get("data")?
                .as_arr()?
                .iter()
                .map(|x| x.as_f64())
                .collect::<Result<Vec<f64>>>()?;
            Ok(KernelDelta::Perturb { side, rhos, vectors: Matrix::from_vec(rows, cols, data)? })
        }
        other => Err(Error::Parse(format!("unknown delta kind '{other}'"))),
    }
}

fn usize_arr_to_json(v: &[usize]) -> Json {
    Json::Arr(v.iter().map(|&i| Json::Num(i as f64)).collect())
}

fn json_to_usize_arr(j: &Json, field: &str) -> Result<Vec<usize>> {
    j.as_arr()
        .map_err(|_| Error::Parse(format!("'{field}' must be an array")))?
        .iter()
        .map(|v| v.as_usize())
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::error::ErrorKind;

    fn roundtrip_request(req: &WireRequest) -> WireRequest {
        let frame = req.to_frame(DEFAULT_MAX_FRAME).unwrap();
        let mut reader = FrameReader::new(DEFAULT_MAX_FRAME);
        reader.push(&frame);
        let payload = reader.next().unwrap().unwrap();
        assert!(reader.next().unwrap().is_none(), "exactly one frame expected");
        WireRequest::from_payload(&payload).unwrap()
    }

    /// Round-trip fidelity check without PartialEq on the envelope types:
    /// encode → frame → decode → re-encode must reproduce the JSON text.
    fn assert_request_stable(req: &WireRequest) {
        let decoded = roundtrip_request(req);
        assert_eq!(req.encode().to_string(), decoded.encode().to_string());
        assert_eq!(req.id(), decoded.id());
    }

    fn assert_response_stable(resp: &WireResponse) {
        let frame = resp.to_frame(DEFAULT_MAX_FRAME).unwrap();
        let mut reader = FrameReader::new(DEFAULT_MAX_FRAME);
        reader.push(&frame);
        let payload = reader.next().unwrap().unwrap();
        let decoded = WireResponse::from_payload(&payload).unwrap();
        assert_eq!(resp.encode().to_string(), decoded.encode().to_string());
        assert_eq!(resp.id(), decoded.id());
    }

    #[test]
    fn frame_roundtrip_and_partial_delivery() {
        let payload = b"{\"id\":1,\"op\":\"report\"}";
        let frame = encode_frame(payload, DEFAULT_MAX_FRAME).unwrap();
        assert_eq!(frame.len(), LEN_PREFIX_BYTES + payload.len());

        // Byte-at-a-time delivery: no frame until the last byte lands.
        let mut reader = FrameReader::new(DEFAULT_MAX_FRAME);
        for (i, b) in frame.iter().enumerate() {
            reader.push(&[*b]);
            let got = reader.next().unwrap();
            if i + 1 < frame.len() {
                assert!(got.is_none(), "premature frame at byte {i}");
            } else {
                assert_eq!(got.unwrap(), payload);
            }
        }
        assert_eq!(reader.buffered(), 0);
    }

    #[test]
    fn frame_reader_handles_back_to_back_frames() {
        let a = encode_frame(b"first", DEFAULT_MAX_FRAME).unwrap();
        let b = encode_frame(b"second", DEFAULT_MAX_FRAME).unwrap();
        let mut joined = a.clone();
        joined.extend_from_slice(&b);
        let mut reader = FrameReader::new(DEFAULT_MAX_FRAME);
        reader.push(&joined);
        assert_eq!(reader.next().unwrap().unwrap(), b"first");
        assert_eq!(reader.next().unwrap().unwrap(), b"second");
        assert!(reader.next().unwrap().is_none());
    }

    #[test]
    fn oversized_frames_rejected_on_both_sides() {
        let cap = 16;
        assert!(matches!(
            encode_frame(&[0u8; 17], cap),
            Err(Error::Invalid(_))
        ));
        // Reader rejects from the prefix alone, before any payload bytes.
        let mut reader = FrameReader::new(cap);
        reader.push(&17u32.to_be_bytes());
        assert!(matches!(reader.next(), Err(Error::Parse(_))));
    }

    #[test]
    fn truncated_prefix_is_pending_not_error() {
        let mut reader = FrameReader::new(DEFAULT_MAX_FRAME);
        reader.push(&[0x00, 0x00]);
        assert!(reader.next().unwrap().is_none());
        assert_eq!(reader.buffered(), 2);
    }

    #[test]
    fn request_roundtrip_every_op_and_mode() {
        let modes = vec![
            SampleMode::Exact,
            SampleMode::Mcmc { steps: 4000 },
            SampleMode::LowRank { rank: 7 },
            SampleMode::Map,
        ];
        for (i, mode) in modes.into_iter().enumerate() {
            assert_request_stable(&WireRequest::Sample {
                id: i as u64,
                tenant: "news".into(),
                k: 5,
                mode,
                include: vec![1],
                exclude: vec![4, 9],
                budget_ms: Some(50),
            });
        }
        assert_request_stable(&WireRequest::Sample {
            id: 10,
            tenant: "bare".into(),
            k: 3,
            mode: SampleMode::Exact,
            include: vec![],
            exclude: vec![],
            budget_ms: None,
        });
        assert_request_stable(&WireRequest::Marginals { id: 11, tenant: "news".into() });
        assert_request_stable(&WireRequest::Report { id: 12 });
        assert_request_stable(&WireRequest::Shutdown { id: 13 });
    }

    #[test]
    fn request_roundtrip_every_delta_kind() {
        let deltas = vec![
            KernelDelta::AddItem { side: 0, row: vec![0.1, -0.2], diag: 1.5 },
            KernelDelta::RemoveItem { side: 1, index: 3 },
            KernelDelta::RetireItem { side: 0, index: 2, damping: 0.25 },
            KernelDelta::Perturb {
                side: 0,
                rhos: vec![0.5, -0.125],
                vectors: Matrix::from_vec(3, 2, vec![1.0, 0.0, 0.5, -0.5, 0.0, 1.0]).unwrap(),
            },
        ];
        for (i, delta) in deltas.into_iter().enumerate() {
            assert_request_stable(&WireRequest::PublishDelta {
                id: i as u64,
                tenant: "news".into(),
                delta,
            });
        }
    }

    #[test]
    fn map_op_is_sugar_for_map_mode() {
        let j = Json::parse(r#"{"id": 4, "op": "map", "tenant": "t", "k": 3}"#).unwrap();
        match WireRequest::decode(&j).unwrap() {
            WireRequest::Sample { mode, k, .. } => {
                assert!(matches!(mode, SampleMode::Map));
                assert_eq!(k, 3);
            }
            other => panic!("expected sample, got {other:?}"),
        }
    }

    #[test]
    fn mode_accepts_bare_string_and_object() {
        let j = Json::parse(
            r#"{"id": 1, "op": "sample", "tenant": "t", "k": 2, "mode": "mcmc"}"#,
        )
        .unwrap();
        match WireRequest::decode(&j).unwrap() {
            WireRequest::Sample { mode: SampleMode::Mcmc { .. }, .. } => {}
            other => panic!("expected mcmc default-steps, got {other:?}"),
        }
        // lowrank as a bare string has no rank: payload error, not panic.
        let j = Json::parse(
            r#"{"id": 1, "op": "sample", "tenant": "t", "k": 2, "mode": "lowrank"}"#,
        )
        .unwrap();
        assert!(WireRequest::decode(&j).is_err());
    }

    #[test]
    fn malformed_payloads_are_clean_errors() {
        // Non-UTF8 payload.
        assert!(matches!(
            WireRequest::from_payload(&[0xff, 0xfe, 0x01]),
            Err(Error::Parse(_))
        ));
        // Garbage JSON.
        assert!(WireRequest::from_payload(b"{nope").is_err());
        // Valid JSON, missing op.
        assert!(WireRequest::from_payload(b"{\"id\": 1}").is_err());
        // Unknown op.
        assert!(matches!(
            WireRequest::from_payload(b"{\"id\": 1, \"op\": \"steal\"}"),
            Err(Error::Parse(_))
        ));
        // Negative k.
        assert!(
            WireRequest::from_payload(b"{\"id\": 1, \"op\": \"sample\", \"tenant\": \"t\", \"k\": -2}")
                .is_err()
        );
        // Unknown delta kind.
        assert!(WireRequest::from_payload(
            b"{\"id\": 1, \"op\": \"publish_delta\", \"tenant\": \"t\", \"delta\": {\"kind\": \"x\", \"side\": 0}}"
        )
        .is_err());
    }

    #[test]
    fn response_roundtrip_every_variant() {
        assert_response_stable(&WireResponse::Items { id: 1, items: vec![0, 4, 9] });
        assert_response_stable(&WireResponse::Marginals {
            id: 2,
            marginals: vec![0.25, 0.5, 0.125],
        });
        assert_response_stable(&WireResponse::Delta {
            id: 3,
            generation: 17,
            incremental: true,
            depth: 4,
        });
        assert_response_stable(&WireResponse::Report {
            id: 4,
            report: "accepted=3\nline two \"quoted\"".into(),
        });
        assert_response_stable(&WireResponse::ShuttingDown { id: 5 });
        assert_response_stable(&WireResponse::Failure {
            id: 6,
            kind: "throttled".into(),
            retryable: true,
            message: "tenant 'a': rate limit 10/s exceeded".into(),
        });
    }

    #[test]
    fn error_envelope_preserves_kind_and_retryability() {
        let cases: Vec<Error> = vec![
            Error::Shape("s".into()),
            Error::Numerical("n".into()),
            Error::Invalid("i".into()),
            Error::Io(std::io::Error::new(std::io::ErrorKind::Other, "io")),
            Error::Parse("p".into()),
            Error::Runtime("r".into()),
            Error::Service("sv".into()),
            Error::Rejected("rj".into()),
            Error::Deadline("d".into()),
            Error::Throttled("t".into()),
        ];
        for err in cases {
            let resp = WireResponse::from_error(9, &err);
            let back = match resp {
                WireResponse::Failure { ref kind, ref message, .. } => {
                    decode_error(kind, message)
                }
                _ => unreachable!(),
            };
            assert_eq!(back.kind(), err.kind(), "kind survives the wire: {err}");
            assert_eq!(
                back.is_retryable(),
                err.is_retryable(),
                "retryability survives the wire: {err}"
            );
        }
        // Unknown kind from a newer peer degrades to Service.
        assert_eq!(decode_error("gizmo", "m").kind(), ErrorKind::Service);
    }

    #[test]
    fn into_items_reconstructs_typed_errors() {
        let ok = WireResponse::Items { id: 1, items: vec![2, 5] };
        assert_eq!(ok.into_items().unwrap(), vec![2, 5]);
        let throttled = WireResponse::Failure {
            id: 2,
            kind: "throttled".into(),
            retryable: true,
            message: "back off".into(),
        };
        let err = throttled.into_items().unwrap_err();
        assert_eq!(err.kind(), ErrorKind::Throttled);
        assert!(err.is_retryable());
        let wrong = WireResponse::ShuttingDown { id: 3 };
        assert!(wrong.into_items().is_err());
    }
}
