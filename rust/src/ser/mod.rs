//! Serialization substrate: JSON (artifact manifests, configs, results),
//! binary matrix/dataset IO, and the length-prefixed TCP wire protocol.

pub mod json;
pub mod matio;
pub mod wire;

pub use json::Json;
pub use wire::{FrameReader, WireRequest, WireResponse, DEFAULT_MAX_FRAME};
