//! Serialization substrate: JSON (artifact manifests, configs, results)
//! and binary matrix/dataset IO.

pub mod json;
pub mod matio;

pub use json::Json;
