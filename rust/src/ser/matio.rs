//! Binary matrix and dataset IO.
//!
//! Format `KDM1` (krondpp matrix v1): magic, u64 rows, u64 cols, then
//! little-endian f64 data row-major. Datasets (`KDS1`) store the ground-set
//! size and each subset as a u32 length + u32 indices. Both formats are
//! written atomically (tmp + rename) so partially-written artifacts are
//! never observed by concurrent readers.

use crate::error::{Error, Result};
use crate::linalg::Matrix;
use std::io::{BufReader, BufWriter, Read, Write};
use std::path::Path;

const MATRIX_MAGIC: &[u8; 4] = b"KDM1";
const DATASET_MAGIC: &[u8; 4] = b"KDS1";

/// Write a matrix to `path`.
pub fn write_matrix(path: &Path, m: &Matrix) -> Result<()> {
    let tmp = tmp_path(path);
    {
        let f = std::fs::File::create(&tmp)?;
        let mut w = BufWriter::new(f);
        w.write_all(MATRIX_MAGIC)?;
        w.write_all(&(m.rows() as u64).to_le_bytes())?;
        w.write_all(&(m.cols() as u64).to_le_bytes())?;
        for &v in m.as_slice() {
            w.write_all(&v.to_le_bytes())?;
        }
        w.flush()?;
    }
    std::fs::rename(&tmp, path)?;
    Ok(())
}

/// Read a matrix from `path`.
pub fn read_matrix(path: &Path) -> Result<Matrix> {
    let f = std::fs::File::open(path)?;
    let mut r = BufReader::new(f);
    let mut magic = [0u8; 4];
    r.read_exact(&mut magic)?;
    if &magic != MATRIX_MAGIC {
        return Err(Error::Parse(format!("{}: not a KDM1 matrix file", path.display())));
    }
    let rows = read_u64(&mut r)? as usize;
    let cols = read_u64(&mut r)? as usize;
    let count = rows.checked_mul(cols).ok_or_else(|| Error::Parse("matrix too large".into()))?;
    let mut data = vec![0.0f64; count];
    let mut buf = [0u8; 8];
    for (idx, v) in data.iter_mut().enumerate() {
        r.read_exact(&mut buf)?;
        let x = f64::from_le_bytes(buf);
        // Reject poison at the ingestion boundary: a NaN/±inf entry would
        // otherwise propagate silently into the eigensolver and wedge every
        // epoch built from this matrix.
        if !x.is_finite() {
            return Err(Error::Invalid(format!(
                "{}: non-finite entry {x} at ({}, {})",
                path.display(),
                idx / cols.max(1),
                idx % cols.max(1)
            )));
        }
        *v = x;
    }
    Matrix::from_vec(rows, cols, data)
}

/// Write a training set (list of subsets over `{0..n}`).
pub fn write_dataset(path: &Path, n: usize, subsets: &[Vec<usize>]) -> Result<()> {
    let tmp = tmp_path(path);
    {
        let f = std::fs::File::create(&tmp)?;
        let mut w = BufWriter::new(f);
        w.write_all(DATASET_MAGIC)?;
        w.write_all(&(n as u64).to_le_bytes())?;
        w.write_all(&(subsets.len() as u64).to_le_bytes())?;
        for s in subsets {
            w.write_all(&(s.len() as u32).to_le_bytes())?;
            for &i in s {
                if i >= n {
                    return Err(Error::Invalid(format!("dataset item {i} out of range {n}")));
                }
                w.write_all(&(i as u32).to_le_bytes())?;
            }
        }
        w.flush()?;
    }
    std::fs::rename(&tmp, path)?;
    Ok(())
}

/// Read a training set; returns `(ground_set_size, subsets)`.
pub fn read_dataset(path: &Path) -> Result<(usize, Vec<Vec<usize>>)> {
    let f = std::fs::File::open(path)?;
    let mut r = BufReader::new(f);
    let mut magic = [0u8; 4];
    r.read_exact(&mut magic)?;
    if &magic != DATASET_MAGIC {
        return Err(Error::Parse(format!("{}: not a KDS1 dataset file", path.display())));
    }
    let n = read_u64(&mut r)? as usize;
    let count = read_u64(&mut r)? as usize;
    let mut subsets = Vec::with_capacity(count);
    for _ in 0..count {
        let k = read_u32(&mut r)? as usize;
        let mut s = Vec::with_capacity(k);
        for _ in 0..k {
            let idx = read_u32(&mut r)? as usize;
            if idx >= n {
                return Err(Error::Parse(format!("dataset item {idx} out of range {n}")));
            }
            s.push(idx);
        }
        subsets.push(s);
    }
    Ok((n, subsets))
}

/// Write a simple CSV: header row + f64 rows. Used by the figure harness.
pub fn write_csv(path: &Path, header: &[&str], rows: &[Vec<f64>]) -> Result<()> {
    let tmp = tmp_path(path);
    {
        let f = std::fs::File::create(&tmp)?;
        let mut w = BufWriter::new(f);
        writeln!(w, "{}", header.join(","))?;
        for row in rows {
            let line: Vec<String> = row.iter().map(|v| format!("{v}")).collect();
            writeln!(w, "{}", line.join(","))?;
        }
        w.flush()?;
    }
    std::fs::rename(&tmp, path)?;
    Ok(())
}

fn read_u64(r: &mut impl Read) -> Result<u64> {
    let mut buf = [0u8; 8];
    r.read_exact(&mut buf)?;
    Ok(u64::from_le_bytes(buf))
}

fn read_u32(r: &mut impl Read) -> Result<u32> {
    let mut buf = [0u8; 4];
    r.read_exact(&mut buf)?;
    Ok(u32::from_le_bytes(buf))
}

fn tmp_path(path: &Path) -> std::path::PathBuf {
    let mut tmp = path.to_path_buf();
    let name = format!(
        ".{}.tmp-{}",
        path.file_name().map(|s| s.to_string_lossy().into_owned()).unwrap_or_default(),
        std::process::id()
    );
    tmp.set_file_name(name);
    tmp
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmpdir() -> std::path::PathBuf {
        let dir = std::env::temp_dir().join(format!("krondpp-matio-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        dir
    }

    #[test]
    fn matrix_roundtrip() {
        let dir = tmpdir();
        let path = dir.join("m.kdm");
        let m = Matrix::from_fn(7, 5, |i, j| (i * 5 + j) as f64 * 0.5 - 3.0);
        write_matrix(&path, &m).unwrap();
        let back = read_matrix(&path).unwrap();
        assert_eq!(m, back);
    }

    #[test]
    fn dataset_roundtrip() {
        let dir = tmpdir();
        let path = dir.join("d.kds");
        let subsets = vec![vec![0, 3, 4], vec![], vec![9]];
        write_dataset(&path, 10, &subsets).unwrap();
        let (n, back) = read_dataset(&path).unwrap();
        assert_eq!(n, 10);
        assert_eq!(back, subsets);
    }

    #[test]
    fn dataset_rejects_out_of_range() {
        let dir = tmpdir();
        let path = dir.join("bad.kds");
        assert!(write_dataset(&path, 3, &[vec![5]]).is_err());
    }

    #[test]
    fn non_finite_entries_rejected_with_index() {
        let dir = tmpdir();
        for (name, bad, row, col) in
            [("nan.kdm", f64::NAN, 1usize, 2usize), ("inf.kdm", f64::NEG_INFINITY, 0, 1)]
        {
            let path = dir.join(name);
            let mut m = Matrix::from_fn(3, 4, |i, j| (i + j) as f64);
            m.set(row, col, bad);
            // write_matrix writes raw bytes, so poison survives to disk.
            write_matrix(&path, &m).unwrap();
            let err = read_matrix(&path).unwrap_err();
            let msg = err.to_string();
            assert!(matches!(err, Error::Invalid(_)), "{name}: {msg}");
            assert!(
                msg.contains(&format!("({row}, {col})")),
                "{name}: offending index missing from '{msg}'"
            );
        }
    }

    #[test]
    fn wrong_magic_rejected() {
        let dir = tmpdir();
        let path = dir.join("x.kdm");
        std::fs::write(&path, b"NOPE and more").unwrap();
        assert!(read_matrix(&path).is_err());
        assert!(read_dataset(&path).is_err());
    }

    #[test]
    fn csv_writes_expected_text() {
        let dir = tmpdir();
        let path = dir.join("r.csv");
        write_csv(&path, &["iter", "nll"], &[vec![1.0, -10.5], vec![2.0, -9.0]]).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        assert!(text.starts_with("iter,nll\n1,-10.5\n2,-9\n"));
    }
}
