//! # krondpp — Kronecker Determinantal Point Processes
//!
//! A production-grade reproduction of *"Kronecker Determinantal Point
//! Processes"* (Mariet & Sra, NIPS 2016): DPP kernels structured as
//! `L = L₁ ⊗ L₂ (⊗ L₃)`, with
//!
//! - exact sampling in `O(N^{3/2} + Nk³)` (m=2) / `O(Nk³)` (m=3),
//!   served by an incremental, batched, multi-threaded engine,
//! - KRK-Picard kernel learning with Θ-free compressed statistics:
//!   `O(nκ³ + nκ² + N₁³+N₂³)` batch (below the paper's `O(nκ³ + N²)`,
//!   Thm. 3.3 — the `N×N` Θ is never materialized) /
//!   `O(Nκ² + N^{3/2})` stochastic time,
//! - the Picard, Joint-Picard and EM baselines the paper compares against,
//! - a multi-tenant serving coordinator (diverse-recommendation service
//!   over a registry of named kernels with epoch-published hot swaps) and
//!   learning orchestrator on top,
//! - a PJRT runtime that executes JAX/Pallas-authored, AOT-lowered HLO
//!   artifacts for the contraction hot paths.
//!
//! ## Paper → module map
//!
//! | Paper | Module |
//! |---|---|
//! | §2, Prop. 2.1–2.4: Kronecker algebra, `Tr₁`/`Tr₂` (Def. 2.3) | [`linalg::kron`] |
//! | Cor. 2.2: factored eigendecomposition of `L₁ ⊗ L₂ (⊗ L₃)` | [`dpp::kernel`] |
//! | Eq. 3 (objective `φ`), Eq. 4 (gradient `Θ − (L+I)⁻¹`) | [`dpp::likelihood`] |
//! | App. B contractions, Θ-free compressed statistics | [`learn::stats`] |
//! | Alg. 1 / Prop. 3.1 / Thm. 3.2: KRK-Picard block ascent | [`learn::krk`] |
//! | §3.1.1: step-size-`a` generalization, m = 3 multiblock | [`learn::krk3`] |
//! | Thm. 3.3 (2nd half): stochastic/minibatch KRK updates | [`learn::krk_stochastic`] |
//! | §3.2 / Alg. 3 / App. C: Joint-Picard | [`learn::joint`] |
//! | §3.3: SUKP subset clustering (memory–time trade-off) | [`learn::clustering`] |
//! | §4 / Alg. 2: exact sampling after Hough et al., k-DPPs | [`dpp::sampler`] |
//! | §4 cost table: `O(N^{3/2})` / `O(N)` preprocessing | [`dpp::kernel`] + [`linalg::kron`] |
//! | §4 baseline: insert/delete MCMC chain (ref. [13]) | [`dpp::mcmc`] |
//! | Approximate sampler zoo: MCMC / low-rank spectral projection behind one [`dpp::SamplerBackend`] | [`dpp::backend`] |
//! | Greedy MAP inference: `argmax det(L_Y)` (Kulesza–Taskar §5.2; fast greedy after Chen et al.) | [`dpp::map`] |
//! | Conditioning `A ⊆ Y, B ∩ Y = ∅` (Borodin–Rains; Kulesza–Taskar §2.4) | [`dpp::condition`] |
//! | Marginal kernel `K = L(L+I)⁻¹`, factored diagonals/blocks | [`dpp::kernel`] ([`dpp::KernelEigen`]) |
//! | k-DPP phase 1: elementary symmetric polynomials (ref. [16]) | [`dpp::elementary`] |
//! | §5 experiment protocols (init, synthetic data, figures) | [`learn::init`], [`data`], [`figures`] |
//! | Baselines: full Picard (ref. [25]), EM (ref. [10]) | [`learn::picard`], [`learn::em`] |
//! | Catalog churn as rank-r kernel deltas (add/remove/retire/perturb) | [`dpp::delta`] |
//! | Rank-r factor up/downdates + secular eigen refresh | [`linalg::cholesky`], [`linalg::eigen_update`] |
//!
//! ## Zero-copy linalg core
//!
//! Everything above bottoms out in [`linalg`]: borrowed stride-aware views
//! ([`linalg::MatRef`]/[`linalg::MatMut`]; sub-blocks and transposes are
//! O(1)), a packed register-tiled GEMM ([`linalg::matmul::gemm_into`],
//! 8×4 f64 micro-kernel, row-panel parallelism, bitwise thread-count
//! invariant), and a two-stage symmetric eigensolver
//! ([`linalg::eigen::SymEigen`]: blocked Householder tridiagonalization
//! whose trailing updates are GEMMs, plus tql2 with parallel rotation
//! replay). Steady-state hot paths — the sampler's phase 2, the KRK-Picard
//! half-updates, the likelihood sweep — run allocation-free through
//! caller-held scratches (see DESIGN.md §1 and `tests/alloc_free.rs`).
//!
//! ## Sampling engine
//!
//! [`dpp::Sampler`] eigendecomposes once per kernel (the §4 preprocessing),
//! then draws through an incremental phase 2: selection weights are
//! maintained by rank-1 downdates and the basis contraction is a single
//! `O(Nk)` Householder reflection
//! ([`linalg::qr::contract_orthonormal_coord`]) instead of an `O(Nk²)`
//! re-orthonormalization. Per-draw buffers live in a caller-held
//! [`dpp::SampleScratch`]; [`dpp::Sampler::sample_batch`] fans draws across
//! threads with one deterministic RNG stream per draw, so results are
//! reproducible regardless of thread count.
//!
//! ## Conditional inference
//!
//! [`dpp::ConditionedSampler`] draws from `P(Y | A ⊆ Y, B ∩ Y = ∅)` —
//! the slate-filling query — via a Schur-complement conditional kernel on
//! the restricted ground set, assembled from factored bordered-block
//! gathers (never a dense `N×N` object) and sampled through the same
//! engine. [`dpp::KernelEigen`] answers marginal queries factored:
//! [`dpp::KernelEigen::inclusion_probabilities_into`] computes all `N`
//! diagonals of `K = L(L+I)⁻¹` in `O(N·(N₁+N₂))` as two GEMMs over
//! squared eigenvector matrices, and
//! [`dpp::KernelEigen::marginal_block_into`] serves `κ×κ` slate
//! probabilities.
//!
//! The serving stack
//! ([`coordinator`]) is multi-tenant and constraint-aware end to end: a
//! [`coordinator::KernelRegistry`] publishes generation-stamped epochs
//! (kernel + cached eigendecomposition + sampler + factored
//! marginal-diagonal table) that readers grab with an `Arc` clone — hot
//! swaps and LRU eviction never block the draw path — while workers
//! reuse one scratch pair each and coalesce `(tenant, k, constraint,
//! mode)` request groups through [`dpp::Sampler::sample_k_many`] /
//! [`dpp::ConditionedSampler::sample_k_each`], sharing one conditioning
//! setup per slate context; [`coordinator::DppService::marginals`] serves
//! each tenant's cached inclusion probabilities. Every request picks a
//! [`dpp::SampleMode`] from the sampler zoo ([`dpp::backend`]): exact
//! spectral draws, per-draw MCMC chains, low-rank spectral projection, or
//! the deterministic greedy MAP slate ([`dpp::map`]) — gated per tenant
//! by a [`coordinator::ModePolicy`], counted per mode in the metrics, and
//! validated against enumeration by the statistical conformance harness
//! (`tests/sampler_conformance.rs`). Catalog churn rides the same epochs
//! incrementally: a [`dpp::KernelDelta`] (item add/remove/retire, rank-r
//! perturbation) published through
//! [`coordinator::KernelRegistry::publish_delta`] updates the kernel
//! exactly and refreshes the cached factor eigendecomposition by a
//! deflation + secular-equation solve ([`linalg::eigen_update`],
//! `O(r·N₁²)` vs `O(N₁³)`), with a depth budget forcing periodic exact
//! republishes — the substrate behind streaming learning
//! ([`coordinator::LearningJob::spawn_streaming`]) and the CLI `churn`
//! command.
//!
//! See `README.md` for the architecture tour and quickstart,
//! `DESIGN.md` for the architecture and `EXPERIMENTS.md` for the
//! paper-reproduction results.

pub mod bench_util;
pub mod cli;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod dpp;
pub mod error;
pub mod exec;
pub mod figures;
pub mod learn;
pub mod linalg;
pub mod rng;
pub mod runtime;
pub mod ser;
pub mod testing;

pub use error::{Error, Result};
pub use linalg::Matrix;
