//! # krondpp — Kronecker Determinantal Point Processes
//!
//! A production-grade reproduction of *"Kronecker Determinantal Point
//! Processes"* (Mariet & Sra, NIPS 2016): DPP kernels structured as
//! `L = L₁ ⊗ L₂ (⊗ L₃)`, with
//!
//! - exact sampling in `O(N^{3/2} + Nk³)` (m=2) / `O(Nk³)` (m=3),
//! - KRK-Picard kernel learning in `O(nκ³ + N²)` batch /
//!   `O(Nκ² + N^{3/2})` stochastic time (Thm. 3.3),
//! - the Picard, Joint-Picard and EM baselines the paper compares against,
//! - a serving coordinator (diverse-recommendation service) and learning
//!   orchestrator on top,
//! - a PJRT runtime that executes JAX/Pallas-authored, AOT-lowered HLO
//!   artifacts for the contraction hot paths.
//!
//! See `DESIGN.md` for the architecture and `EXPERIMENTS.md` for the
//! paper-reproduction results.

pub mod bench_util;
pub mod cli;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod dpp;
pub mod error;
pub mod exec;
pub mod figures;
pub mod learn;
pub mod linalg;
pub mod rng;
pub mod runtime;
pub mod ser;
pub mod testing;

pub use error::{Error, Result};
pub use linalg::Matrix;
