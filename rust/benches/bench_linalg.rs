//! Substrate rooflines: matmul, Cholesky, eigen, Kronecker contractions.
//!
//! These are the primitives every learner is built from; their throughput
//! bounds everything in EXPERIMENTS.md §Perf. GFLOP/s annotations use the
//! standard op counts (2n³ GEMM, n³/3 Cholesky, ~(4+4/3)n³ two-stage
//! eigensolve).
//!
//! Two before/after sections track the zero-copy core refactor per commit:
//! packed register-tiled GEMM vs. the legacy blocked kernel, and the
//! blocked two-stage eigensolver vs. sequential tred2/tql2 — speedup
//! ratios land in `BENCH_linalg.json` (uploaded as a CI artifact by the
//! bench smoke job).
//!
//! Knobs: `KRONDPP_BENCH_BUDGET_MS` (per-case budget),
//! `KRONDPP_BENCH_MAX_N` (skip cases above this size — the CI smoke job
//! sets it low so the run finishes in seconds).

use krondpp::bench_util::{black_box, section, Bencher, Report};
use krondpp::linalg::eigen::SymEigen;
use krondpp::linalg::{cholesky, kron, matmul, simd, trisolve, Matrix};
use krondpp::rng::Rng;

fn spd(n: usize, rng: &mut Rng) -> Matrix {
    let mut m = rng.paper_init_kernel(n);
    m.scale_mut(1.0 / n as f64);
    m.add_diag_mut(0.5);
    m
}

fn max_n() -> usize {
    krondpp::bench_util::bench_max_n()
}

fn main() {
    let b = Bencher::default();
    let mut rng = Rng::new(1);
    let mut report = Report::new();
    let cap = max_n();

    section("GEMM: packed register-tiled vs legacy blocked (C = A·B)");
    for n in [128usize, 512, 1024] {
        if n > cap {
            println!("  (skipped N={n}: KRONDPP_BENCH_MAX_N)");
            continue;
        }
        let a = rng.normal_matrix(n, n);
        let x = rng.normal_matrix(n, n);
        let flops = 2.0 * (n as f64).powi(3);
        let packed = b.run(&format!("gemm packed {n}x{n}"), || {
            black_box(matmul::matmul(&a, &x).unwrap());
        });
        let pg = flops / packed.secs() / 1e9;
        println!("    -> {pg:.2} GFLOP/s");
        let legacy = b.run(&format!("gemm legacy {n}x{n}"), || {
            black_box(matmul::matmul_blocked_legacy(&a, &x));
        });
        let lg = flops / legacy.secs() / 1e9;
        let speedup = legacy.secs() / packed.secs();
        println!("    -> {lg:.2} GFLOP/s  (packed speedup {speedup:.2}x)");
        report.case(&packed, &[("gflops", pg)]);
        report.case(&legacy, &[("gflops", lg)]);
        report.derived(&format!("gemm_packed_vs_legacy_speedup_n{n}"), speedup);
    }

    // ---------------------------------------------------------------
    // Per-arch SIMD dispatch: scalar oracle vs the detected kernel.
    // Both arms run in this process through the `_with` seam (the env
    // override `KRONDPP_FORCE_SCALAR` can only pin a whole process), so
    // the ratio isolates the micro-kernel itself — packing, blocking and
    // threading are identical, and the results agree bitwise.
    // ---------------------------------------------------------------
    let act = simd::active();
    let ora = simd::forced_scalar();
    section(&format!(
        "SIMD dispatch: {} ({}x{} tile) vs scalar oracle ({}x{})",
        act.name(),
        act.mr(),
        act.nr(),
        ora.mr(),
        ora.nr()
    ));
    let simd_active = if std::ptr::eq(act, ora) {
        println!("  (dispatch resolved to scalar — ratios will be ~1.0x)");
        false
    } else {
        true
    };
    report.derived("simd_dispatch_is_vectorized", if simd_active { 1.0 } else { 0.0 });
    let mut gs = matmul::GemmScratch::new();
    for n in [128usize, 512, 1024] {
        if n > cap {
            println!("  (skipped N={n}: KRONDPP_BENCH_MAX_N)");
            continue;
        }
        let a = rng.normal_matrix(n, n);
        let x = rng.normal_matrix(n, n);
        let mut c = Matrix::zeros(n, n);
        let flops = 2.0 * (n as f64).powi(3);
        let disp = b.run(&format!("gemm dispatched {n}x{n}"), || {
            matmul::gemm_into_with(c.view_mut(), 1.0, a.view(), x.view(), false, &mut gs, act);
            black_box(&c);
        });
        let scal = b.run(&format!("gemm forced-scalar {n}x{n}"), || {
            matmul::gemm_into_with(c.view_mut(), 1.0, a.view(), x.view(), false, &mut gs, ora);
            black_box(&c);
        });
        let (dg, sg) = (flops / disp.secs() / 1e9, flops / scal.secs() / 1e9);
        let speedup = scal.secs() / disp.secs();
        println!("    -> {dg:.2} vs {sg:.2} GFLOP/s  (simd speedup {speedup:.2}x)");
        report.case(&disp, &[("gflops", dg)]);
        report.case(&scal, &[("gflops", sg)]);
        report.derived(&format!("gemm_simd_vs_scalar_speedup_n{n}"), speedup);
    }
    for n in [256usize, 512] {
        if n > cap {
            println!("  (skipped N={n}: KRONDPP_BENCH_MAX_N)");
            continue;
        }
        // Lower-triangular solve with a wide RHS: the row-axpy sweep.
        let mut l = spd(n, &mut rng);
        for i in 0..n {
            for j in (i + 1)..n {
                l.set(i, j, 0.0);
            }
        }
        let rhs = rng.normal_matrix(n, n);
        let mut xbuf = rhs.clone();
        let disp = b.run(&format!("trisolve dispatched {n} ({n} rhs)"), || {
            xbuf.as_mut_slice().copy_from_slice(rhs.as_slice());
            trisolve::solve_lower_in_place_with(l.view(), &mut xbuf, false, act);
            black_box(&xbuf);
        });
        let scal = b.run(&format!("trisolve forced-scalar {n}"), || {
            xbuf.as_mut_slice().copy_from_slice(rhs.as_slice());
            trisolve::solve_lower_in_place_with(l.view(), &mut xbuf, false, ora);
            black_box(&xbuf);
        });
        let speedup = scal.secs() / disp.secs();
        println!("    -> trisolve simd speedup {speedup:.2}x");
        report.case(&disp, &[]);
        report.case(&scal, &[]);
        report.derived(&format!("trisolve_simd_vs_scalar_speedup_n{n}"), speedup);
    }
    {
        // Marginal-diagonal grid sweep (λ/(1+λ) weights + squared-
        // eigenvector GEMM feeds) on a Kron2 kernel.
        let (n1, n2) = (48usize.min(cap), 48usize.min(cap));
        let k1 = spd(n1, &mut rng);
        let k2 = spd(n2, &mut rng);
        let eig = krondpp::dpp::Kernel::Kron2(k1, k2).eigen().unwrap();
        let mut scratch = krondpp::dpp::MarginalScratch::new();
        let mut diag = Vec::new();
        let disp = b.run(&format!("marginal grid dispatched {n1}x{n2}"), || {
            eig.inclusion_probabilities_into_with(&mut diag, &mut scratch, act);
            black_box(&diag);
        });
        let scal = b.run(&format!("marginal grid forced-scalar {n1}x{n2}"), || {
            eig.inclusion_probabilities_into_with(&mut diag, &mut scratch, ora);
            black_box(&diag);
        });
        let speedup = scal.secs() / disp.secs();
        println!("    -> marginal-grid simd speedup {speedup:.2}x");
        report.case(&disp, &[]);
        report.case(&scal, &[]);
        report.derived("marginal_grid_simd_vs_scalar_speedup", speedup);
    }

    section("symmetric eigendecomposition: blocked two-stage vs tred2/tql2");
    for n in [128usize, 256, 512] {
        if n > cap {
            println!("  (skipped N={n}: KRONDPP_BENCH_MAX_N)");
            continue;
        }
        let a = spd(n, &mut rng);
        let par = b.run(&format!("eigh blocked {n}"), || {
            black_box(SymEigen::new_blocked(&a).unwrap());
        });
        let seq = b.run(&format!("eigh sequential {n}"), || {
            black_box(SymEigen::new_seq(&a).unwrap());
        });
        let speedup = seq.secs() / par.secs();
        println!("    -> blocked speedup {speedup:.2}x");
        report.case(&par, &[]);
        report.case(&seq, &[]);
        report.derived(&format!("eigen_blocked_vs_seq_speedup_n{n}"), speedup);
    }

    section("cholesky factor + inverse");
    for n in [128usize, 256, 512] {
        if n > cap {
            continue;
        }
        let a = spd(n, &mut rng);
        let f = b.run(&format!("cholesky factor {n}"), || {
            black_box(cholesky::Cholesky::factor(&a).unwrap());
        });
        let inv = b.run(&format!("pd inverse {n}"), || {
            black_box(cholesky::inverse_pd(&a).unwrap());
        });
        report.case(&f, &[]);
        report.case(&inv, &[]);
    }

    section("kron contractions (the KRK hot spot, App. B)");
    for (n1, n2) in [(32usize, 32usize), (50, 50), (64, 64)] {
        let n = n1 * n2;
        if n > cap {
            println!("  (skipped N={n}: KRONDPP_BENCH_MAX_N)");
            continue;
        }
        let theta = rng.normal_matrix(n, n);
        let l2 = rng.normal_matrix(n2, n2);
        let w = rng.normal_matrix(n1, n1);
        let stats = b.run(&format!("block_trace (A1) {n1}x{n2} [N={n}]"), || {
            black_box(kron::block_trace(&theta, &l2, n1, n2).unwrap());
        });
        // 2 flops per Θ element.
        let gbs = (n * n) as f64 * 8.0 / stats.secs() / 1e9;
        println!("    -> {gbs:.2} GB/s effective Θ bandwidth");
        report.case(&stats, &[("theta_gbs", gbs)]);
        let wbs = b.run(&format!("weighted_block_sum (A2) {n1}x{n2}"), || {
            black_box(kron::weighted_block_sum(&theta, &w, n1, n2).unwrap());
        });
        report.case(&wbs, &[]);
        let pt = b.run(&format!("partial_trace_1 {n1}x{n2}"), || {
            black_box(kron::partial_trace_1(&theta, n1, n2).unwrap());
        });
        report.case(&pt, &[]);
    }

    section("nearest Kronecker product (Joint-Picard inner loop)");
    for (n1, n2) in [(16usize, 16usize), (32, 32)] {
        if n1 * n2 > cap {
            println!("  (skipped N={}: KRONDPP_BENCH_MAX_N)", n1 * n2);
            continue;
        }
        let a = spd(n1, &mut rng);
        let c = spd(n2, &mut rng);
        let m = kron::kron(&a, &c);
        let stats = b.run(&format!("nkp {n1}x{n2}"), || {
            black_box(krondpp::linalg::nkp::nearest_kronecker(&m, n1, n2, 100, 1e-10).unwrap());
        });
        report.case(&stats, &[]);
    }

    let out = "BENCH_linalg.json";
    match report.write("linalg", out) {
        Ok(()) => println!("\nwrote {out}"),
        Err(e) => eprintln!("\nfailed to write {out}: {e}"),
    }
}
