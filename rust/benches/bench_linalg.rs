//! Substrate rooflines: matmul, Cholesky, eigen, Kronecker contractions.
//!
//! These are the primitives every learner is built from; their throughput
//! bounds everything in EXPERIMENTS.md §Perf. GFLOP/s annotations use the
//! standard op counts (2n³ GEMM, n³/3 Cholesky).

use krondpp::bench_util::{black_box, section, Bencher};
use krondpp::linalg::{cholesky, eigen::SymEigen, kron, matmul, Matrix};
use krondpp::rng::Rng;

fn spd(n: usize, rng: &mut Rng) -> Matrix {
    let mut m = rng.paper_init_kernel(n);
    m.scale_mut(1.0 / n as f64);
    m.add_diag_mut(0.5);
    m
}

fn main() {
    let b = Bencher::default();
    let mut rng = Rng::new(1);

    section("matmul (C = A·B)");
    for n in [128usize, 256, 512, 1024] {
        let a = rng.normal_matrix(n, n);
        let x = rng.normal_matrix(n, n);
        let stats = b.run(&format!("matmul {n}x{n}"), || {
            black_box(matmul::matmul(&a, &x).unwrap());
        });
        let gflops = 2.0 * (n as f64).powi(3) / stats.secs() / 1e9;
        println!("    -> {gflops:.2} GFLOP/s");
    }

    section("cholesky factor + inverse");
    for n in [128usize, 256, 512] {
        let a = spd(n, &mut rng);
        b.run(&format!("cholesky factor {n}"), || {
            black_box(cholesky::Cholesky::factor(&a).unwrap());
        });
        b.run(&format!("pd inverse {n}"), || {
            black_box(cholesky::inverse_pd(&a).unwrap());
        });
    }

    section("symmetric eigendecomposition (tred2/tql2)");
    for n in [64usize, 128, 256] {
        let a = spd(n, &mut rng);
        b.run(&format!("eigh {n}"), || {
            black_box(SymEigen::new(&a).unwrap());
        });
    }

    section("kron contractions (the KRK hot spot, App. B)");
    for (n1, n2) in [(32usize, 32usize), (50, 50), (64, 64)] {
        let n = n1 * n2;
        let theta = rng.normal_matrix(n, n);
        let l2 = rng.normal_matrix(n2, n2);
        let w = rng.normal_matrix(n1, n1);
        let stats = b.run(&format!("block_trace (A1) {n1}x{n2} [N={n}]"), || {
            black_box(kron::block_trace(&theta, &l2, n1, n2).unwrap());
        });
        // 2 flops per Θ element.
        let gbs = (n * n) as f64 * 8.0 / stats.secs() / 1e9;
        println!("    -> {gbs:.2} GB/s effective Θ bandwidth");
        b.run(&format!("weighted_block_sum (A2) {n1}x{n2}"), || {
            black_box(kron::weighted_block_sum(&theta, &w, n1, n2).unwrap());
        });
        b.run(&format!("partial_trace_1 {n1}x{n2}"), || {
            black_box(kron::partial_trace_1(&theta, n1, n2).unwrap());
        });
    }

    section("nearest Kronecker product (Joint-Picard inner loop)");
    for (n1, n2) in [(16usize, 16usize), (32, 32)] {
        let a = spd(n1, &mut rng);
        let c = spd(n2, &mut rng);
        let m = kron::kron(&a, &c);
        b.run(&format!("nkp {n1}x{n2}"), || {
            black_box(krondpp::linalg::nkp::nearest_kronecker(&m, n1, n2, 100, 1e-10).unwrap());
        });
    }
}
