//! §3.3 ablation: dense Θ vs clustered sparse Θ — contraction time and
//! memory across union budgets z, quantifying the memory–time trade-off.

use krondpp::bench_util::{black_box, section, Bencher};
use krondpp::data;
use krondpp::dpp::likelihood::theta_dense;
use krondpp::learn::clustering::{greedy_partition, ClusteredTheta};
use krondpp::linalg::kron;
use krondpp::rng::Rng;

fn main() {
    let b = Bencher { min_iters: 3, ..Default::default() };
    let (n1, n2) = (40usize, 40usize);
    let n = n1 * n2;
    let mut rng = Rng::new(3);
    let truth = data::paper_truth_kernel(n1, n2, &mut rng);
    let train = data::sample_training_set(&truth, 100, 8, 60, &mut rng).unwrap();
    let kappa = train.kappa();
    println!("N={n}, {} subsets, κ={kappa}", train.len());
    let (_l1, l2) = match &truth {
        krondpp::dpp::Kernel::Kron2(a, b) => (a.clone(), b.clone()),
        _ => unreachable!(),
    };

    section("dense path");
    let dense = theta_dense(&truth, &train.subsets).unwrap();
    b.run("theta_dense build", || {
        black_box(theta_dense(&truth, &train.subsets).unwrap());
    });
    b.run("dense A1 contraction", || {
        black_box(kron::block_trace(&dense, &l2, n1, n2).unwrap());
    });
    println!("  dense Θ memory: {:.1} MiB", (n * n * 8) as f64 / (1 << 20) as f64);

    section("clustered path across union budgets z");
    for mult in [2usize, 3, 5] {
        let z = mult * kappa;
        let clusters = greedy_partition(&train.subsets, z).unwrap();
        let ct = ClusteredTheta::build(&truth, &train.subsets, &clusters, n1, n2).unwrap();
        println!(
            "  z={z}: m={} parts, nnz={} ({:.2} MiB)",
            clusters.len(),
            ct.nnz(),
            (ct.nnz() * 12) as f64 / (1 << 20) as f64
        );
        b.run(&format!("clustered build z={z}"), || {
            black_box(
                ClusteredTheta::build(&truth, &train.subsets, &clusters, n1, n2).unwrap(),
            );
        });
        b.run(&format!("clustered A1 z={z}"), || {
            black_box(ct.block_trace(&l2).unwrap());
        });
    }
}
