//! PJRT runtime path vs pure-Rust contractions: does offloading the A1/A2
//! contraction to the AOT-compiled XLA artifact pay at each size? Also
//! measures artifact compile time (one-off) and steady-state dispatch
//! overhead. Requires `make artifacts`.

use krondpp::bench_util::{black_box, section, Bencher};
use krondpp::linalg::kron;
use krondpp::rng::Rng;
use krondpp::runtime::Engine;

fn main() {
    let b = Bencher { min_iters: 3, ..Default::default() };
    let engine = match Engine::load_default() {
        Ok(e) => e,
        Err(err) => {
            println!("runtime benches skipped: {err}");
            return;
        }
    };
    println!("platform: {}", engine.platform());

    section("krk_contractions artifact vs pure Rust");
    let mut rng = Rng::new(1);
    for (n1, n2) in [(8usize, 8usize), (16, 16), (32, 32)] {
        let name = format!("krk_contractions_{n1}x{n2}");
        if !engine.has(&name) {
            println!("  (no artifact {name})");
            continue;
        }
        let n = n1 * n2;
        let theta = rng.normal_matrix(n, n);
        let l1 = rng.normal_matrix(n1, n1);
        let l2 = rng.normal_matrix(n2, n2);
        // Warm the executable cache (compile excluded from steady state).
        let t0 = std::time::Instant::now();
        engine.execute_matrices(&name, &[&theta, &l1, &l2]).unwrap();
        println!("  {name}: first call (compile+run) {:.1} ms", t0.elapsed().as_secs_f64() * 1e3);
        let hlo = b.run(&format!("hlo {name}"), || {
            black_box(engine.execute_matrices(&name, &[&theta, &l1, &l2]).unwrap());
        });
        let cpu = b.run(&format!("rust contractions {n1}x{n2}"), || {
            black_box(kron::block_trace(&theta, &l2, n1, n2).unwrap());
            black_box(kron::weighted_block_sum(&theta, &l1, n1, n2).unwrap());
        });
        println!(
            "    -> hlo/rust ratio {:.2} (dispatch overhead dominates below ~N=1024)",
            hlo.secs() / cpu.secs()
        );
    }

    section("gram + picard_ldl artifacts");
    if engine.has("gram_512x128") {
        let x = rng.normal_matrix(512, 128);
        engine.execute_matrices("gram_512x128", &[&x]).unwrap();
        b.run("hlo gram 512x128", || {
            black_box(engine.execute_matrices("gram_512x128", &[&x]).unwrap());
        });
        b.run("rust gram 512x128", || {
            black_box(krondpp::linalg::matmul::matmul_tn(&x, &x).unwrap());
        });
    }
    if engine.has("picard_ldl_256") {
        let l = rng.normal_matrix(256, 256);
        let d = rng.normal_matrix(256, 256);
        engine.execute_matrices("picard_ldl_256", &[&l, &d]).unwrap();
        b.run("hlo picard_ldl 256", || {
            black_box(engine.execute_matrices("picard_ldl_256", &[&l, &d]).unwrap());
        });
        b.run("rust picard ldl 256", || {
            let ldl = krondpp::linalg::matmul::sandwich(&l, &d, &l).unwrap();
            let mut out = l.clone();
            out += &ldl;
            black_box(out);
        });
    }
}
