//! Sampler-zoo quality-vs-throughput sweep: every backend (exact
//! spectral, MCMC at several chain lengths, low-rank spectral projection
//! at several ranks) measured on both axes —
//!
//! * **quality**: total-variation distance between the empirical subset
//!   histogram and the brute-force enumerated law on a small `N = 9`
//!   Kronecker kernel, plus empirical-marginal max error against the
//!   factored `inclusion_probabilities_into` diagonal at serving scale;
//! * **throughput**: draws/s per backend at `N = 64`, and greedy MAP
//!   slates/s (with the slate's log-determinant objective recorded).
//!
//! The TV rows make the fidelity knobs concrete: MCMC converges toward
//! the exact law as `steps` grows, the projection converges as `rank`
//! approaches `N`, and the throughput rows price each rung. Writes
//! `BENCH_sampler_zoo.json` (see `bench_util::Report`). Honors
//! `KRONDPP_BENCH_BUDGET_MS` (per-case budget; also scales the TV draw
//! counts) and `KRONDPP_BENCH_MAX_N` (skips the serving-scale sections
//! when the catalog exceeds the cap).

use krondpp::bench_util::{
    bench_budget_ms, bench_max_n, black_box, section, Bencher, Report,
};
use krondpp::data;
use krondpp::dpp::{
    map_slate_into, Constraint, Kernel, LowRankBackend, MapScratch, McmcBackend, SampleScratch,
    Sampler, SamplerBackend,
};
use krondpp::linalg::lu;
use krondpp::rng::Rng;
use std::collections::HashMap;
use std::time::Instant;

/// Brute-force law `P(Y) ∝ det(L_Y)` by enumerating all `2^N` subsets
/// (mirrors the conformance harness's oracle; only sane for tiny `N`).
fn subset_law(kernel: &Kernel) -> HashMap<Vec<usize>, f64> {
    let n = kernel.n();
    assert!(n <= 14, "enumeration oracle is O(2^N)");
    let dense = kernel.to_dense();
    let mut law = HashMap::new();
    let mut total = 0.0;
    for mask in 0u32..(1u32 << n) {
        let subset: Vec<usize> = (0..n).filter(|&i| mask >> i & 1 == 1).collect();
        let w = if subset.is_empty() {
            1.0
        } else {
            lu::det(&dense.principal_submatrix(&subset)).unwrap_or(0.0).max(0.0)
        };
        total += w;
        law.insert(subset, w);
    }
    for w in law.values_mut() {
        *w /= total;
    }
    law
}

/// Total-variation distance `½ Σ_Y |p̂(Y) − p(Y)|` between the empirical
/// histogram of `draws` and the enumerated `law`.
fn total_variation(draws: &[Vec<usize>], law: &HashMap<Vec<usize>, f64>) -> f64 {
    let total = draws.len() as f64;
    let mut counts: HashMap<&[usize], f64> = HashMap::new();
    for d in draws {
        *counts.entry(d.as_slice()).or_insert(0.0) += 1.0;
    }
    let mut tv = 0.0;
    for (subset, &p) in law {
        let emp = counts.remove(subset.as_slice()).unwrap_or(0.0) / total;
        tv += (emp - p).abs();
    }
    // Mass the backend put on subsets outside the law's support.
    for c in counts.into_values() {
        tv += c / total;
    }
    0.5 * tv
}

/// Draw `count` samples and time the loop, returning `(draws, draws/s)`.
fn timed_draws<B: SamplerBackend>(
    backend: &B,
    count: usize,
    rng: &mut Rng,
) -> (Vec<Vec<usize>>, f64) {
    let mut scratch = SampleScratch::new();
    let mut out = Vec::new();
    let mut draws = Vec::with_capacity(count);
    let t = Instant::now();
    for _ in 0..count {
        backend.draw_into(None, rng, &mut scratch, &mut out).expect("draw failed");
        draws.push(out.clone());
    }
    let per_s = count as f64 / t.elapsed().as_secs_f64().max(1e-12);
    (draws, per_s)
}

fn max_marginal_err(draws: &[Vec<usize>], truth: &[f64]) -> f64 {
    let total = draws.len() as f64;
    let mut freq = vec![0.0; truth.len()];
    for d in draws {
        for &i in d {
            freq[i] += 1.0;
        }
    }
    freq.iter()
        .zip(truth)
        .map(|(f, t)| (f / total - t).abs())
        .fold(0.0, f64::max)
}

fn main() {
    let b = Bencher { min_iters: 2, ..Default::default() };
    let max_n = bench_max_n();
    let budget_ms = bench_budget_ms();
    let mut report = Report::new();

    section("quality: total variation vs the enumerated law (N = 9)");
    {
        let mut rng = Rng::new(2016);
        let kernel = data::paper_truth_kernel(3, 3, &mut rng);
        let law = subset_law(&kernel);
        // Scale the histogram size with the smoke budget: ~3k draws in CI
        // smoke, ~30k in a full run. TV to the truth scales like
        // O(sqrt(cells / draws)), so even the smoke row separates the
        // fidelity rungs.
        let tv_draws = (budget_ms * 20).clamp(2_000, 40_000);
        println!("{} draws per backend", tv_draws);

        let exact = Sampler::new(&kernel).unwrap();
        let (draws, per_s) = timed_draws(&exact, tv_draws, &mut Rng::new(7));
        let tv = total_variation(&draws, &law);
        println!("  exact                 tv = {tv:.4}  ({per_s:.0} draws/s)");
        report.case_raw("tv exact n9", &[
            ("tv", tv),
            ("draws", tv_draws as f64),
            ("draws_per_s", per_s),
        ]);
        let exact_tv = tv;

        for steps in [25usize, 100, 400] {
            let mcmc = McmcBackend::new(&kernel, Constraint::none(), steps).unwrap();
            let (draws, per_s) = timed_draws(&mcmc, tv_draws, &mut Rng::new(8));
            let tv = total_variation(&draws, &law);
            println!("  mcmc steps={steps:<4}       tv = {tv:.4}  ({per_s:.0} draws/s)");
            report.case_raw(&format!("tv mcmc steps={steps} n9"), &[
                ("tv", tv),
                ("steps", steps as f64),
                ("draws", tv_draws as f64),
                ("draws_per_s", per_s),
            ]);
        }

        for rank in [3usize, 6, 9] {
            let lr = LowRankBackend::new(&kernel, rank, Constraint::none()).unwrap();
            let (draws, per_s) = timed_draws(&lr, tv_draws, &mut Rng::new(9));
            let tv = total_variation(&draws, &law);
            println!("  lowrank rank={rank}        tv = {tv:.4}  ({per_s:.0} draws/s)");
            report.case_raw(&format!("tv lowrank rank={rank} n9"), &[
                ("tv", tv),
                ("rank", rank as f64),
                ("draws", tv_draws as f64),
                ("draws_per_s", per_s),
            ]);
            if rank == kernel.n() {
                // Full-rank projection is the exact sampler in disguise —
                // its TV must sit at the same statistical floor.
                report.derived("full_rank_tv_minus_exact_tv", tv - exact_tv);
            }
        }
    }

    section("marginals + throughput at serving scale (N = 64)");
    if 64 <= max_n {
        let mut rng = Rng::new(64);
        let kernel = data::paper_truth_kernel(8, 8, &mut rng);
        let truth = kernel.eigen().unwrap().inclusion_probabilities();
        let m_draws = (budget_ms * 2).clamp(300, 4_000);

        let exact = Sampler::new(&kernel).unwrap();
        let mcmc = McmcBackend::new(&kernel, Constraint::none(), 200).unwrap();
        let lr = LowRankBackend::new(&kernel, 16, Constraint::none()).unwrap();

        let (draws, _) = timed_draws(&exact, m_draws, &mut Rng::new(11));
        let err = max_marginal_err(&draws, &truth);
        println!("  exact        marginal max-err = {err:.4} over {m_draws} draws");
        report.case_raw("marginal exact n64", &[("max_err", err), ("draws", m_draws as f64)]);
        let (draws, _) = timed_draws(&mcmc, m_draws, &mut Rng::new(12));
        let err = max_marginal_err(&draws, &truth);
        println!("  mcmc s=200   marginal max-err = {err:.4} (chain bias + noise)");
        report.case_raw("marginal mcmc200 n64", &[("max_err", err), ("draws", m_draws as f64)]);
        let (draws, _) = timed_draws(&lr, m_draws, &mut Rng::new(13));
        let err = max_marginal_err(&draws, &truth);
        println!("  lowrank r=16 marginal max-err = {err:.4} (truncation bias + noise)");
        report.case_raw("marginal lowrank16 n64", &[("max_err", err), ("draws", m_draws as f64)]);

        let mut scratch = SampleScratch::new();
        let mut out = Vec::new();
        let mut draw_rng = Rng::new(17);
        let per_iter = 16usize;
        for (name, backend) in [
            ("exact", &exact as &dyn SamplerBackend),
            ("mcmc steps=200", &mcmc as &dyn SamplerBackend),
            ("lowrank rank=16", &lr as &dyn SamplerBackend),
        ] {
            let stats = b.run(&format!("draw {name} (N=64, 16 draws)"), || {
                for _ in 0..per_iter {
                    backend.draw_into(None, &mut draw_rng, &mut scratch, &mut out).unwrap();
                }
                black_box(&out);
            });
            let per_s = per_iter as f64 / stats.secs();
            println!("  {name}: {per_s:.0} draws/s");
            report.case(&stats, &[("draws_per_s", per_s)]);
        }
    } else {
        println!("skipped (N = 64 > KRONDPP_BENCH_MAX_N = {max_n})");
    }

    section("greedy MAP slate throughput (N = 64, k = 10)");
    if 64 <= max_n {
        let mut rng = Rng::new(65);
        let kernel = data::paper_truth_kernel(8, 8, &mut rng);
        let mut scratch = MapScratch::new();
        let mut slate = Vec::new();
        let none = Constraint::none();
        let stats = b.run("map k=10 (N=64)", || {
            black_box(
                map_slate_into(&kernel, Some(10), &none, &mut scratch, &mut slate).unwrap(),
            );
        });
        let ld =
            map_slate_into(&kernel, Some(10), &none, &mut scratch, &mut slate).unwrap();
        let per_s = 1.0 / stats.secs();
        println!("  {per_s:.0} slates/s, log det(L_S) = {ld:.4}");
        report.case(&stats, &[("slates_per_s", per_s), ("slate_logdet", ld)]);

        let c = Constraint::new(vec![3, 20], vec![10, 41]).unwrap();
        let stats = b.run("map k=10 constrained (N=64)", || {
            black_box(
                map_slate_into(&kernel, Some(10), &c, &mut scratch, &mut slate).unwrap(),
            );
        });
        report.case(&stats, &[("slates_per_s", 1.0 / stats.secs())]);
    } else {
        println!("skipped (N = 64 > KRONDPP_BENCH_MAX_N = {max_n})");
    }

    report.write("sampler_zoo", "BENCH_sampler_zoo.json").expect("write BENCH_sampler_zoo.json");
    println!("\nwrote BENCH_sampler_zoo.json");
}
