//! Ablations for the design choices DESIGN.md calls out.
//!
//! 1. **Step size `a`** (§3.1.1): the paper observes the admissible range
//!    of `a` is wider for KRK-Picard than for Picard and shrinks with N.
//!    We sweep `a` and report, per algorithm and size, the largest step
//!    that keeps 5 iterations PD-and-ascending (the PD safeguard is
//!    disabled here so the raw update is measured).
//! 2. **Block-coordinate vs joint** updates: likelihood after a fixed
//!    wall-clock budget for KRK vs Joint-Picard.
//! 3. **Minibatch size** for stochastic KRK: progress per wall-clock.

use krondpp::data;
use krondpp::dpp::likelihood::log_likelihood;
use krondpp::dpp::Kernel;
use krondpp::learn::traits::TrainingSet;
use krondpp::learn::{init, JointPicard, KrkPicard, KrkStochastic, Learner, Picard};
use krondpp::linalg::kron;
use krondpp::rng::Rng;

/// Is `a` admissible for this learner on this problem: 5 iterations with
/// monotone likelihood (tolerating tiny noise) and no numerical failure?
fn admissible(mut learner: Box<dyn Learner>, data: &TrainingSet) -> bool {
    let mut prev = match log_likelihood(&learner.kernel(), &data.subsets) {
        Ok(v) => v,
        Err(_) => return false,
    };
    for _ in 0..5 {
        if learner.step(data).is_err() {
            return false;
        }
        match log_likelihood(&learner.kernel(), &data.subsets) {
            Ok(ll) if ll >= prev - 1e-6 => prev = ll,
            _ => return false,
        }
    }
    true
}

fn main() {
    println!("=== ablation 1: admissible step sizes (5 monotone iterations) ===");
    println!("{:<8} {:>14} {:>14}", "N", "picard a_max", "krk a_max");
    for (n1, n2) in [(12usize, 12usize), (20, 20), (28, 28)] {
        let n = n1 * n2;
        let mut rng = Rng::new(100 + n as u64);
        let truth = data::paper_truth_kernel(n1, n2, &mut rng);
        let data =
            data::sample_training_set(&truth, 40, (n / 30).max(2), (n / 6).max(4), &mut rng)
                .unwrap();
        let l1 = init::paper_subkernel(n1, &mut rng);
        let l2 = init::paper_subkernel(n2, &mut rng);
        let l0 = kron::kron(&l1, &l2);
        let sweep =
            [1.0, 1.2, 1.4, 1.6, 1.8, 2.0, 2.4, 2.8, 3.4, 4.0, 5.0, 6.5, 8.0];
        let mut pic_max = 0.0;
        let mut krk_max = 0.0;
        for &a in &sweep {
            let mut pic = Picard::new(l0.clone(), a).unwrap();
            pic.safeguard = false;
            if admissible(Box::new(pic), &data) {
                pic_max = a;
            }
            let mut krk = KrkPicard::new(l1.clone(), l2.clone(), a).unwrap();
            krk.safeguard = false;
            if admissible(Box::new(krk), &data) {
                krk_max = a;
            }
        }
        println!("{n:<8} {pic_max:>14.1} {krk_max:>14.1}");
    }

    println!("\n=== ablation 2: KRK vs Joint-Picard, equal wall-clock ===");
    {
        let (n1, n2) = (24usize, 24usize);
        let mut rng = Rng::new(7);
        let truth = data::paper_truth_kernel(n1, n2, &mut rng);
        let data = data::sample_training_set(&truth, 50, 6, 70, &mut rng).unwrap();
        let l1 = init::paper_subkernel(n1, &mut rng);
        let l2 = init::paper_subkernel(n2, &mut rng);
        let budget = std::time::Duration::from_millis(400);
        for (name, mut learner) in [
            (
                "krk",
                Box::new(KrkPicard::new(l1.clone(), l2.clone(), 1.0).unwrap())
                    as Box<dyn Learner>,
            ),
            ("joint", Box::new(JointPicard::new(l1.clone(), l2.clone(), 1.0).unwrap())),
        ] {
            let t0 = std::time::Instant::now();
            let mut iters = 0;
            while t0.elapsed() < budget {
                learner.step(&data).unwrap();
                iters += 1;
            }
            let ll = log_likelihood(&learner.kernel(), &data.subsets).unwrap();
            println!("  {name:<6} {iters:>4} iters in {budget:?} -> ll {ll:.4}");
        }
    }

    println!("\n=== ablation 3: stochastic minibatch size (fixed 300ms budget) ===");
    {
        let (n1, n2) = (24usize, 24usize);
        let mut rng = Rng::new(9);
        let truth = data::paper_truth_kernel(n1, n2, &mut rng);
        let data = data::sample_training_set(&truth, 60, 6, 70, &mut rng).unwrap();
        let l1 = init::paper_subkernel(n1, &mut rng);
        let l2 = init::paper_subkernel(n2, &mut rng);
        for mb in [1usize, 4, 16, 60] {
            let mut learner = KrkStochastic::new(l1.clone(), l2.clone(), 0.7, mb, 11);
            let t0 = std::time::Instant::now();
            let mut iters = 0;
            while t0.elapsed() < std::time::Duration::from_millis(300) {
                learner.step(&data).unwrap();
                iters += 1;
            }
            let ll = log_likelihood(&learner.kernel(), &data.subsets).unwrap();
            println!("  minibatch {mb:>3}: {iters:>5} updates -> ll {ll:.4}");
        }
    }

    println!("\n=== ablation 4: m=3 factorization (Kron3 learner) ===");
    {
        let mut rng = Rng::new(13);
        let mk = |n: usize, rng: &mut Rng| {
            let mut l = rng.paper_init_kernel(n);
            l.scale_mut(1.2 / n as f64);
            l.add_diag_mut(0.35);
            l
        };
        let truth =
            Kernel::Kron3(mk(6, &mut rng), mk(6, &mut rng), mk(6, &mut rng)); // N = 216
        let sampler = krondpp::dpp::Sampler::new(&truth).unwrap();
        let subsets: Vec<Vec<usize>> = (0..40).map(|_| sampler.sample(&mut rng)).collect();
        let data = TrainingSet::new(216, subsets).unwrap();
        let mut k3 = krondpp::learn::Krk3Picard::new(
            mk(6, &mut rng),
            mk(6, &mut rng),
            mk(6, &mut rng),
            1.0,
        )
        .unwrap();
        let t0 = std::time::Instant::now();
        let r = k3.run(&data, 8, 0.0).unwrap();
        println!(
            "  krk3 (N=216): ll {:.4} -> {:.4} in 8 iters ({:.1} ms/iter, wall {:.2}s)",
            r.history[0].log_likelihood,
            r.final_ll(),
            r.mean_iter_secs() * 1e3,
            t0.elapsed().as_secs_f64()
        );
        // m=2 on the same data with a (36, 6) split for comparison.
        let mut k2 =
            KrkPicard::new(mk(36, &mut rng), mk(6, &mut rng), 1.0).unwrap();
        let r2 = k2.run(&data, 8, 0.0).unwrap();
        println!(
            "  krk2 (36x6):  ll {:.4} -> {:.4} in 8 iters ({:.1} ms/iter)",
            r2.history[0].log_likelihood,
            r2.final_ll(),
            r2.mean_iter_secs() * 1e3
        );
    }
}
