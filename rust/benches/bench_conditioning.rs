//! Conditional-inference benches: conditioned-draw throughput and setup
//! cost as the forced include set `|A|` grows, plus the factored-vs-dense
//! marginal-diagonal sweep (the `O(N·(N₁+N₂))` two-GEMM path against the
//! `O(N³)` dense `K = L(L+I)⁻¹` oracle).
//!
//! Writes `BENCH_conditioning.json` (see `bench_util::Report`) so CI can
//! track the conditioning trajectory per commit. Honors the smoke-mode
//! env vars: `KRONDPP_BENCH_BUDGET_MS` (per-case budget) and
//! `KRONDPP_BENCH_MAX_N` (catalog cap; the dense sweep additionally skips
//! sizes whose `O(N³)` oracle would dwarf the budget).

use krondpp::bench_util::{bench_max_n, black_box, section, Bencher, Report};
use krondpp::data;
use krondpp::dpp::{
    ConditionScratch, ConditionedSampler, Constraint, MarginalScratch, SampleScratch, Sampler,
};
use krondpp::rng::Rng;

fn main() {
    let b = Bencher { min_iters: 2, ..Default::default() };
    let max_n = bench_max_n();
    let mut report = Report::new();

    section("conditioned-draw throughput vs |A| (Kron2, fixed |B| = 8)");
    {
        let side = [32usize, 16, 8, 4].into_iter().find(|s| s * s <= max_n).unwrap_or(4);
        let n = side * side;
        let mut rng = Rng::new(2016);
        let kernel = data::paper_truth_kernel(side, side, &mut rng);
        println!("catalog N = {n}");
        let exclude: Vec<usize> = (0..8.min(n / 4)).map(|i| n - 1 - 2 * i).collect();
        let mut cond_scratch = ConditionScratch::new();
        let mut scratch = SampleScratch::new();
        for a_size in [0usize, 1, 2, 4, 8] {
            if a_size >= n / 4 {
                continue;
            }
            let include: Vec<usize> = (0..a_size).map(|i| 3 * i).collect();
            let constraint = Constraint::new(include, exclude.clone()).unwrap();
            let setup = b.run(&format!("setup |A|={a_size} (N={n})"), || {
                black_box(
                    ConditionedSampler::new_with_scratch(
                        &kernel,
                        constraint.clone(),
                        &mut cond_scratch,
                    )
                    .unwrap(),
                );
            });
            let cs =
                ConditionedSampler::new_with_scratch(&kernel, constraint, &mut cond_scratch)
                    .unwrap();
            let mut draw_rng = Rng::new(7);
            let mut out = Vec::new();
            let draws_per_iter = 16usize;
            let draw = b.run(&format!("draw  |A|={a_size} (N={n}, 16 draws)"), || {
                for _ in 0..draws_per_iter {
                    cs.sample_into(&mut draw_rng, &mut scratch, &mut out);
                }
                black_box(&out);
            });
            let draws_per_s = draws_per_iter as f64 / draw.secs();
            println!("  |A|={a_size}: {draws_per_s:.0} conditioned draws/s");
            report.case(&setup, &[("a_size", a_size as f64), ("n", n as f64)]);
            report.case(&draw, &[
                ("a_size", a_size as f64),
                ("n", n as f64),
                ("draws_per_s", draws_per_s),
            ]);
        }
    }

    section("factored vs dense marginal diagonal (all N inclusion probabilities)");
    {
        let mut mscratch = MarginalScratch::new();
        let mut diag = Vec::new();
        for side in [16usize, 32, 64] {
            let n = side * side;
            if n > max_n {
                continue;
            }
            let mut rng = Rng::new(side as u64);
            let kernel = data::paper_truth_kernel(side, side, &mut rng);
            let sampler = Sampler::new(&kernel).unwrap();
            let fact = b.run(&format!("factored diag N={n}"), || {
                sampler.eigen().inclusion_probabilities_into(&mut diag, &mut mscratch);
                black_box(&diag);
            });
            report.case(&fact, &[("n", n as f64)]);
            // The dense oracle inverts (L+I): O(N³). Keep it to sizes the
            // smoke budget tolerates.
            if n <= 1024 {
                let dense = b.run(&format!("dense    diag N={n}"), || {
                    black_box(kernel.marginal_kernel().unwrap());
                });
                report.case(&dense, &[("n", n as f64)]);
                let speedup = dense.secs() / fact.secs();
                println!("  N={n}: factored is {speedup:.0}x faster than dense");
                report.derived(&format!("factored_vs_dense_diag_speedup_n{n}"), speedup);
            }
        }
    }

    report
        .write("conditioning", "BENCH_conditioning.json")
        .expect("write BENCH_conditioning.json");
    println!("\nwrote BENCH_conditioning.json");
}
