//! Coordinator throughput/latency: requests/s across worker counts and
//! batch policies on a fixed synthetic workload (offered-load sweep).

use krondpp::bench_util::section;
use krondpp::config::ServiceConfig;
use krondpp::coordinator::{DppService, SampleRequest};
use krondpp::data;
use krondpp::rng::Rng;
use std::sync::Arc;
use std::time::Instant;

fn drive(svc: &Arc<DppService>, requests: usize, k: usize) -> (f64, f64, f64) {
    drive_ks(svc, &vec![k; requests])
}

/// Drive one request per entry of `ks` (request i asks for k = ks[i]).
fn drive_ks(svc: &Arc<DppService>, ks: &[usize]) -> (f64, f64, f64) {
    let t0 = Instant::now();
    let tickets: Vec<_> =
        ks.iter().map(|&k| svc.submit(SampleRequest { k }).unwrap()).collect();
    for t in tickets {
        t.wait().unwrap();
    }
    let wall = t0.elapsed().as_secs_f64();
    let p95 = svc.metrics().latency.quantile(0.95).as_secs_f64() * 1e3;
    let p50 = svc.metrics().latency.quantile(0.50).as_secs_f64() * 1e3;
    (ks.len() as f64 / wall, p50, p95)
}

fn main() {
    let mut rng = Rng::new(1);
    let kernel = data::paper_truth_kernel(32, 32, &mut rng); // N = 1024
    let requests = 3000;

    section("throughput vs workers (k=10, max_batch=32)");
    println!("{:<10} {:>12} {:>10} {:>10}", "workers", "req/s", "p50 ms", "p95 ms");
    for workers in [1usize, 2, 4, 8] {
        let cfg = ServiceConfig {
            workers,
            max_batch: 32,
            batch_window_us: 200,
            queue_capacity: 100_000,
        };
        let svc = Arc::new(DppService::start(&kernel, &cfg, 9).unwrap());
        let (rps, p50, p95) = drive(&svc, requests, 10);
        println!("{workers:<10} {rps:>12.0} {p50:>10.3} {p95:>10.3}");
        drop(svc); // Drop drains + joins
    }

    section("throughput vs max_batch (4 workers, k=10)");
    println!("{:<10} {:>12} {:>10} {:>10}", "max_batch", "req/s", "p50 ms", "p95 ms");
    for max_batch in [1usize, 8, 32, 128] {
        let cfg = ServiceConfig {
            workers: 4,
            max_batch,
            batch_window_us: 200,
            queue_capacity: 100_000,
        };
        let svc = Arc::new(DppService::start(&kernel, &cfg, 9).unwrap());
        let (rps, p50, p95) = drive(&svc, requests, 10);
        println!("{max_batch:<10} {rps:>12.0} {p50:>10.3} {p95:>10.3}");
        drop(svc); // Drop drains + joins
    }

    section("same-k coalescing: uniform vs mixed k (4 workers, max_batch=32)");
    println!("{:<14} {:>12} {:>10} {:>10}", "workload", "req/s", "p50 ms", "p95 ms");
    {
        let cfg = ServiceConfig {
            workers: 4,
            max_batch: 32,
            batch_window_us: 200,
            queue_capacity: 100_000,
        };
        // Uniform k: every batch coalesces into one sample_k_many group.
        let svc = Arc::new(DppService::start(&kernel, &cfg, 9).unwrap());
        let (rps, p50, p95) = drive(&svc, requests, 10);
        println!("{:<14} {rps:>12.0} {p50:>10.3} {p95:>10.3}", "uniform k=10");
        drop(svc);
        // Mixed k: groups shrink, each batch pays several phase-1 setups.
        let ks: Vec<usize> = (0..requests).map(|i| 5 + (i % 4) * 5).collect();
        let svc = Arc::new(DppService::start(&kernel, &cfg, 9).unwrap());
        let (rps, p50, p95) = drive_ks(&svc, &ks);
        println!("{:<14} {rps:>12.0} {p50:>10.3} {p95:>10.3}", "mixed k 5-20");
        drop(svc);
    }

    section("latency vs requested k (4 workers)");
    println!("{:<10} {:>12} {:>10} {:>10}", "k", "req/s", "p50 ms", "p95 ms");
    for k in [5usize, 15, 30, 60] {
        let cfg = ServiceConfig {
            workers: 4,
            max_batch: 32,
            batch_window_us: 200,
            queue_capacity: 100_000,
        };
        let svc = Arc::new(DppService::start(&kernel, &cfg, 9).unwrap());
        let (rps, p50, p95) = drive(&svc, 1200, k);
        println!("{k:<10} {rps:>12.0} {p50:>10.3} {p95:>10.3}");
        drop(svc); // Drop drains + joins
    }
}
