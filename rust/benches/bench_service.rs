//! Coordinator throughput/latency: requests/s across worker counts, batch
//! policies, and tenant counts on fixed synthetic workloads.
//!
//! Writes `BENCH_service.json` (see `bench_util::Report`) so CI can track
//! the serving-path trajectory per commit. Honors the smoke-mode env vars:
//! `KRONDPP_BENCH_BUDGET_MS` scales the request counts down and
//! `KRONDPP_BENCH_MAX_N` caps the catalog size (the EXPERIMENTS.md
//! §Service tables are produced at full budget).

use krondpp::bench_util::{bench_budget_ms, bench_max_n, section, Report};
use krondpp::config::{AdmissionPolicy, ServiceConfig};
use krondpp::coordinator::{
    run_replay, DppService, KernelRegistry, NetConfig, NetServer, SampleRequest, TenantId,
    WireClient,
};
use krondpp::data;
use krondpp::data::workload::{replay, ModeMix, ReplaySpec};
use krondpp::dpp::KernelDelta;
use krondpp::rng::Rng;
use std::sync::Arc;
use std::time::Instant;

fn drive(svc: &Arc<DppService>, requests: usize, k: usize) -> (f64, f64, f64) {
    let reqs: Vec<SampleRequest> = (0..requests).map(|_| SampleRequest::new(k)).collect();
    drive_reqs(svc, &reqs)
}

/// Drive one request per entry of `reqs`, wait for all, and report
/// (req/s, p50 ms, p95 ms) from the service's latency histogram.
fn drive_reqs(svc: &Arc<DppService>, reqs: &[SampleRequest]) -> (f64, f64, f64) {
    let t0 = Instant::now();
    let tickets: Vec<_> = reqs.iter().map(|r| svc.submit(r.clone()).unwrap()).collect();
    for t in tickets {
        t.wait().unwrap();
    }
    let wall = t0.elapsed().as_secs_f64();
    let p95 = svc.metrics().latency.quantile(0.95).as_secs_f64() * 1e3;
    let p50 = svc.metrics().latency.quantile(0.50).as_secs_f64() * 1e3;
    (reqs.len() as f64 / wall, p50, p95)
}

fn main() {
    // Smoke gating: CI runs with a small budget and capped N; full runs
    // reproduce the EXPERIMENTS.md tables.
    let budget_ms = bench_budget_ms();
    let max_n = bench_max_n();
    // Largest square catalog within the cap (the sweeps can't skip the
    // kernel the way bench_linalg skips cases, so shrink it instead).
    let side = [32usize, 16, 8, 4].into_iter().find(|s| s * s <= max_n).unwrap_or(4);
    let (n1, n2) = (side, side);
    let requests = (budget_ms * 2).clamp(200, 3000);
    let mut rng = Rng::new(1);
    let kernel = data::paper_truth_kernel(n1, n2, &mut rng);
    println!("catalog N = {} ({} requests per case)", n1 * n2, requests);
    let mut report = Report::new();

    section("throughput vs workers (k=10, max_batch=32)");
    println!("{:<10} {:>12} {:>10} {:>10}", "workers", "req/s", "p50 ms", "p95 ms");
    for workers in [1usize, 2, 4, 8] {
        let cfg = ServiceConfig {
            workers,
            max_batch: 32,
            batch_window_us: 200,
            queue_capacity: 100_000,
            ..ServiceConfig::default()
        };
        let svc = Arc::new(DppService::start(&kernel, &cfg, 9).unwrap());
        let (rps, p50, p95) = drive(&svc, requests, 10);
        println!("{workers:<10} {rps:>12.0} {p50:>10.3} {p95:>10.3}");
        report.case_raw(
            &format!("workers_{workers}"),
            &[("req_per_s", rps), ("p50_ms", p50), ("p95_ms", p95)],
        );
        drop(svc); // Drop drains + joins
    }

    section("throughput vs max_batch (4 workers, k=10)");
    println!("{:<10} {:>12} {:>10} {:>10}", "max_batch", "req/s", "p50 ms", "p95 ms");
    for max_batch in [1usize, 8, 32, 128] {
        let cfg = ServiceConfig {
            workers: 4,
            max_batch,
            batch_window_us: 200,
            queue_capacity: 100_000,
            ..ServiceConfig::default()
        };
        let svc = Arc::new(DppService::start(&kernel, &cfg, 9).unwrap());
        let (rps, p50, p95) = drive(&svc, requests, 10);
        println!("{max_batch:<10} {rps:>12.0} {p50:>10.3} {p95:>10.3}");
        report.case_raw(
            &format!("max_batch_{max_batch}"),
            &[("req_per_s", rps), ("p50_ms", p50), ("p95_ms", p95)],
        );
        drop(svc); // Drop drains + joins
    }

    section("same-k coalescing: uniform vs mixed k (4 workers, max_batch=32)");
    println!("{:<14} {:>12} {:>10} {:>10}", "workload", "req/s", "p50 ms", "p95 ms");
    {
        let cfg = ServiceConfig {
            workers: 4,
            max_batch: 32,
            batch_window_us: 200,
            queue_capacity: 100_000,
            ..ServiceConfig::default()
        };
        // Uniform k: every batch coalesces into one sample_k_many group.
        let svc = Arc::new(DppService::start(&kernel, &cfg, 9).unwrap());
        let (rps, p50, p95) = drive(&svc, requests, 10);
        println!("{:<14} {rps:>12.0} {p50:>10.3} {p95:>10.3}", "uniform k=10");
        report.case_raw(
            "coalescing_uniform_k10",
            &[("req_per_s", rps), ("p50_ms", p50), ("p95_ms", p95)],
        );
        drop(svc);
        // Mixed k: groups shrink, each batch pays several phase-1 setups.
        let reqs: Vec<SampleRequest> =
            (0..requests).map(|i| SampleRequest::new(5 + (i % 4) * 5)).collect();
        let svc = Arc::new(DppService::start(&kernel, &cfg, 9).unwrap());
        let (rps, p50, p95) = drive_reqs(&svc, &reqs);
        println!("{:<14} {rps:>12.0} {p50:>10.3} {p95:>10.3}", "mixed k 5-20");
        report.case_raw(
            "coalescing_mixed_k",
            &[("req_per_s", rps), ("p50_ms", p50), ("p95_ms", p95)],
        );
        drop(svc);
    }

    section("multi-tenant: coalescing vs tenant count (4 workers, k=10, fixed total load)");
    println!("{:<10} {:>12} {:>10} {:>10}", "tenants", "req/s", "p50 ms", "p95 ms");
    let mut tenant_rps = Vec::new();
    for tenants in [1usize, 2, 4, 8] {
        let cfg = ServiceConfig {
            workers: 4,
            max_batch: 32,
            batch_window_us: 200,
            queue_capacity: 100_000,
            ..ServiceConfig::default()
        };
        // Same-size catalogs; traffic round-robins across tenants, so
        // per-(tenant, k) coalesced groups shrink as tenant count grows.
        let svc = Arc::new(DppService::start(&kernel, &cfg, 9).unwrap());
        let mut ids: Vec<TenantId> = vec![svc.tenant("default").unwrap()];
        for t in 1..tenants {
            let mut trng = Rng::new(100 + t as u64);
            let k = data::paper_truth_kernel(n1, n2, &mut trng);
            ids.push(svc.add_tenant(&format!("tenant-{t}"), &k).unwrap());
        }
        let reqs: Vec<SampleRequest> = (0..requests)
            .map(|i| SampleRequest::for_tenant(ids[i % ids.len()], 10))
            .collect();
        let (rps, p50, p95) = drive_reqs(&svc, &reqs);
        println!("{tenants:<10} {rps:>12.0} {p50:>10.3} {p95:>10.3}");
        report.case_raw(
            &format!("tenants_{tenants}"),
            &[("req_per_s", rps), ("p50_ms", p50), ("p95_ms", p95)],
        );
        tenant_rps.push(rps);
        drop(svc);
    }
    if let (Some(&first), Some(&last)) = (tenant_rps.first(), tenant_rps.last()) {
        // < 1.0 quantifies the coalescing loss from spreading one load
        // over 8 catalogs (each (tenant, k) group is 1/8 the size).
        report.derived("tenant8_vs_tenant1_throughput", last / first.max(1e-12));
    }

    section("hot-swap publish under load (2 tenants, k=10)");
    {
        let cfg = ServiceConfig {
            workers: 4,
            max_batch: 32,
            batch_window_us: 200,
            queue_capacity: 100_000,
            ..ServiceConfig::default()
        };
        let svc = Arc::new(DppService::start(&kernel, &cfg, 9).unwrap());
        let mut trng = Rng::new(7);
        let other = data::paper_truth_kernel(n1, n2, &mut trng);
        let b = svc.add_tenant("b", &other).unwrap();
        // Publisher thread republished tenant b the whole time; requests
        // target both tenants and must not stall on the publishes.
        let stop = Arc::new(std::sync::atomic::AtomicBool::new(false));
        let publisher = {
            let svc2 = Arc::clone(&svc);
            let stop2 = Arc::clone(&stop);
            std::thread::spawn(move || {
                let mut publishes = 0u64;
                let mut prng = Rng::new(11);
                while !stop2.load(std::sync::atomic::Ordering::SeqCst) {
                    let k = data::paper_truth_kernel(n1, n2, &mut prng);
                    svc2.publish(b, &k).unwrap();
                    publishes += 1;
                }
                publishes
            })
        };
        let ids = [svc.tenant("default").unwrap(), b];
        let reqs: Vec<SampleRequest> = (0..requests)
            .map(|i| SampleRequest::for_tenant(ids[i % 2], 10))
            .collect();
        let (rps, p50, p95) = drive_reqs(&svc, &reqs);
        stop.store(true, std::sync::atomic::Ordering::SeqCst);
        let publishes = publisher.join().unwrap();
        println!(
            "served {rps:.0} req/s (p50 {p50:.3} ms, p95 {p95:.3} ms) through {publishes} live epoch publishes"
        );
        report.case_raw(
            "hot_swap_under_load",
            &[
                ("req_per_s", rps),
                ("p50_ms", p50),
                ("p95_ms", p95),
                ("publishes", publishes as f64),
            ],
        );
        drop(svc);
    }

    section("degraded-mode serving: healthy vs forced fallback (4 workers, k=10)");
    println!("{:<14} {:>12} {:>10} {:>10}", "mode", "req/s", "p50 ms", "p95 ms");
    {
        let cfg = ServiceConfig {
            workers: 4,
            max_batch: 32,
            batch_window_us: 200,
            queue_capacity: 100_000,
            ..ServiceConfig::default()
        };
        // Healthy baseline: primary exact path, shared per-batch eigen.
        let svc = Arc::new(DppService::start(&kernel, &cfg, 9).unwrap());
        let (h_rps, p50, p95) = drive(&svc, requests, 10);
        println!("{:<14} {h_rps:>12.0} {p50:>10.3} {p95:>10.3}", "healthy");
        report.case_raw(
            "degraded_healthy",
            &[("req_per_s", h_rps), ("p50_ms", p50), ("p95_ms", p95)],
        );
        drop(svc);
        // Forced-open breaker: every request detours through the
        // fallback chain's first regularization rung, paying a fresh
        // `L + εI` eigendecomposition per coalesced group. The ratio
        // below is the degraded-mode capacity an operator keeps when
        // quarantining a tenant's primary path.
        let svc = Arc::new(DppService::start(&kernel, &cfg, 9).unwrap());
        let t = svc.tenant("default").unwrap();
        svc.force_degraded(t, true).unwrap();
        let (d_rps, p50, p95) = drive(&svc, requests, 10);
        println!("{:<14} {d_rps:>12.0} {p50:>10.3} {p95:>10.3}", "forced");
        println!("  {}", svc.metrics().fallback.summary());
        report.case_raw(
            "degraded_forced",
            &[("req_per_s", d_rps), ("p50_ms", p50), ("p95_ms", p95)],
        );
        report.derived("degraded_vs_healthy_throughput", d_rps / h_rps.max(1e-12));
        drop(svc);
    }

    section("validated publish latency (finite scan + spectrum sanity, live service)");
    {
        let cfg = ServiceConfig {
            workers: 2,
            max_batch: 32,
            batch_window_us: 200,
            queue_capacity: 100_000,
            ..ServiceConfig::default()
        };
        let svc = Arc::new(DppService::start(&kernel, &cfg, 9).unwrap());
        let t = svc.tenant("default").unwrap();
        let publishes = (budget_ms / 2).clamp(20, 200) as usize;
        // Pre-build candidates so the loop times only the validated
        // publish: finite scan + factor eigensolves + spectrum check +
        // epoch swap + history record.
        let mut prng = Rng::new(23);
        let candidates: Vec<_> =
            (0..publishes).map(|_| data::paper_truth_kernel(n1, n2, &mut prng)).collect();
        let t0 = Instant::now();
        for c in &candidates {
            svc.publish(t, c).unwrap();
        }
        let wall = t0.elapsed().as_secs_f64();
        let mean_ms = wall * 1e3 / publishes as f64;
        println!("{publishes} validated publishes: {mean_ms:.3} ms mean ({:.0}/s)", publishes as f64 / wall);
        report.case_raw(
            "validated_publish",
            &[("publish_per_s", publishes as f64 / wall), ("mean_ms", mean_ms)],
        );
        drop(svc);
    }

    section("churn: incremental delta publish vs full re-eigendecomposition");
    println!(
        "{:<10} {:>6} {:>14} {:>14} {:>10}",
        "factor n", "rank", "delta ms", "full ms", "speedup"
    );
    let mut last_speedup = None;
    for s in [8usize, 16, 32, 64] {
        if s * s > max_n {
            println!("(skipping factor n={s}: catalog {} > KRONDPP_BENCH_MAX_N={max_n})", s * s);
            continue;
        }
        let cfg = ServiceConfig {
            workers: 2,
            max_batch: 32,
            batch_window_us: 200,
            queue_capacity: 100_000,
            ..ServiceConfig::default()
        };
        // The sweep times the steady-state secular-refresh path, so lift
        // the periodic exact-republish depth bound out of the window
        // (production keeps it; see DESIGN.md §2.4 on the drift budget).
        let mut registry =
            KernelRegistry::with_history(cfg.max_resident_epochs, cfg.epoch_history);
        registry.set_max_delta_depth(u64::MAX);
        let registry = Arc::new(registry);
        let mut crng = Rng::new(31);
        let churn_kernel = data::paper_truth_kernel(s, s, &mut crng);
        registry.add_tenant("default", &churn_kernel).unwrap();
        let svc = Arc::new(DppService::start_with_registry(registry, &cfg, 9).unwrap());
        let t = svc.tenant("default").unwrap();
        let publishes = (budget_ms / 2).clamp(10, 100) as usize;
        const RANK: usize = 2;
        // Pre-built rank-2 feedback perturbations, small enough to keep
        // the factor PD across the whole run.
        let deltas: Vec<KernelDelta> = (0..publishes)
            .map(|_| KernelDelta::Perturb {
                side: 0,
                rhos: vec![1.0, -0.5],
                vectors: crng.uniform_matrix(s, RANK, -0.01, 0.01),
            })
            .collect();
        let t0 = Instant::now();
        for d in &deltas {
            svc.publish_delta(t, d).unwrap();
        }
        let delta_ms = t0.elapsed().as_secs_f64() * 1e3 / publishes as f64;
        let incremental = svc.registry().delta_incremental();
        // Full republishes of same-shape kernels: two fresh factor
        // eigensolves + validation per publish (the pre-delta baseline).
        let candidates: Vec<_> =
            (0..publishes).map(|_| data::paper_truth_kernel(s, s, &mut crng)).collect();
        let t0 = Instant::now();
        for c in &candidates {
            svc.publish(t, c).unwrap();
        }
        let full_ms = t0.elapsed().as_secs_f64() * 1e3 / publishes as f64;
        let speedup = full_ms / delta_ms.max(1e-9);
        println!(
            "{s:<10} {RANK:>6} {delta_ms:>14.3} {full_ms:>14.3} {speedup:>10.2}  \
             ({incremental}/{publishes} incremental)"
        );
        report.case_raw(
            &format!("churn_factor_{s}"),
            &[
                ("delta_publish_ms", delta_ms),
                ("full_publish_ms", full_ms),
                ("speedup", speedup),
                ("incremental_fraction", incremental as f64 / publishes as f64),
            ],
        );
        last_speedup = Some(speedup);
        drop(svc);
    }
    if let Some(sp) = last_speedup {
        // Keyed on the largest swept factor — the r ≪ N regime the delta
        // path exists for.
        report.derived("delta_publish_vs_full_speedup", sp);
    }

    section("latency vs requested k (4 workers)");
    println!("{:<10} {:>12} {:>10} {:>10}", "k", "req/s", "p50 ms", "p95 ms");
    for k in [5usize, 15, 30, 60] {
        let cfg = ServiceConfig {
            workers: 4,
            max_batch: 32,
            batch_window_us: 200,
            queue_capacity: 100_000,
            ..ServiceConfig::default()
        };
        let svc = Arc::new(DppService::start(&kernel, &cfg, 9).unwrap());
        let (rps, p50, p95) = drive(&svc, (requests * 2) / 5, k);
        println!("{k:<10} {rps:>12.0} {p50:>10.3} {p95:>10.3}");
        report.case_raw(
            &format!("latency_k{k}"),
            &[("req_per_s", rps), ("p50_ms", p50), ("p95_ms", p95)],
        );
        drop(svc); // Drop drains + joins
    }

    section("TCP saturation sweep (open-loop replay over loopback, 2 tenants)");
    {
        // Two-tenant overload drama: "hog" is token-bucket rate-limited and
        // Zipf-dominant; "quiet" is unlimited with an SLO. The open-loop
        // client offers multiples of measured capacity — past 1x the hog's
        // excess must shed as retryable `throttled` at admission while the
        // quiet tenant's p99 holds.
        let cfg = ServiceConfig {
            workers: 4,
            max_batch: 32,
            batch_window_us: 200,
            queue_capacity: 10_000,
            shed_queue_depth: 2_000,
            ..ServiceConfig::default()
        };
        let svc = Arc::new(DppService::start(&kernel, &cfg, 9).unwrap());
        let mut trng = Rng::new(17);
        let hog = svc
            .add_tenant("hog", &data::paper_truth_kernel(n1, n2, &mut trng))
            .unwrap();
        let quiet = svc
            .add_tenant("quiet", &data::paper_truth_kernel(n1, n2, &mut trng))
            .unwrap();

        // Closed-loop capacity probe on the default tenant sizes the sweep.
        let (base_hz, _, _) = drive(&svc, (requests / 4).max(100), 5);

        // Hog is capped at a quarter of capacity; quiet keeps a 250 ms SLO.
        svc.set_admission(
            hog,
            AdmissionPolicy { rate_hz: base_hz * 0.25, burst: base_hz * 0.125, ..AdmissionPolicy::default() },
        )
        .unwrap();
        svc.set_admission(quiet, AdmissionPolicy { slo_ms: 250, ..AdmissionPolicy::default() })
            .unwrap();

        let server =
            NetServer::start(Arc::clone(&svc), "127.0.0.1:0", NetConfig::default()).unwrap();
        let addr = server.local_addr().to_string();
        let names = vec!["hog".to_string(), "quiet".to_string()];
        let per_point = requests.clamp(150, 1500);

        println!(
            "capacity ~{base_hz:.0}/s; hog capped at {:.0}/s; quiet SLO 250 ms",
            base_hz * 0.25
        );
        println!(
            "{:<8} {:>12} {:>14} {:>10} {:>12} {:>12}",
            "offered", "offered/s", "sustained/s", "shed", "hog p99 ms", "quiet p99 ms"
        );
        let mut quiet_p99_at_max = 0.0f64;
        let mut shed_at_max = 0.0f64;
        for mult in [0.5f64, 1.0, 2.0, 4.0] {
            let offered_hz = base_hz * mult;
            let spec = ReplaySpec {
                tenants: 2,
                // s=3 puts ~89% of traffic on the hog: the quiet tenant
                // stays inside remaining capacity even at 4x offered.
                zipf_s: 3.0,
                rate_hz: offered_hz,
                count: per_point,
                k_lo: 2,
                k_hi: 8,
                constraint_fraction: 0.2,
                ground_size: n1 * n2,
                mode_mix: ModeMix { exact: 0.7, mcmc: 0.0, lowrank: 0.2, map: 0.1 },
                ..ReplaySpec::default()
            };
            let trace = replay(&spec, &mut Rng::new(4000 + (mult * 10.0) as u64));
            let out = run_replay(&addr, &names, &trace, 4, None).unwrap();
            let hog_t = &out.per_tenant[0];
            let quiet_t = &out.per_tenant[1];
            println!(
                "{:<8} {:>12.0} {:>14.0} {:>10.3} {:>12.3} {:>12.3}",
                format!("{mult}x"),
                offered_hz,
                out.sustained_hz(),
                out.shed_fraction(),
                hog_t.p99_ms,
                quiet_t.p99_ms,
            );
            report.case_raw(
                &format!("saturation_{}x", mult),
                &[
                    ("offered_hz", offered_hz),
                    ("sustained_hz", out.sustained_hz()),
                    ("shed_fraction", out.shed_fraction()),
                    ("completed", out.completed as f64),
                    ("throttled", out.throttled as f64),
                    ("failed", out.failed as f64),
                    ("hog_p50_ms", hog_t.p50_ms),
                    ("hog_p99_ms", hog_t.p99_ms),
                    ("quiet_p50_ms", quiet_t.p50_ms),
                    ("quiet_p99_ms", quiet_t.p99_ms),
                ],
            );
            quiet_p99_at_max = quiet_t.p99_ms;
            shed_at_max = out.shed_fraction();
        }
        // The two headline curves: overload must shed (throttled, not
        // queued) and the below-limit tenant's tail must hold its SLO.
        report.derived("saturation_shed_fraction_at_4x", shed_at_max);
        report.derived("saturation_quiet_p99_ms_at_4x", quiet_p99_at_max);

        // Graceful wire drain ends the sweep.
        let mut ctl = WireClient::connect(&addr).unwrap();
        ctl.shutdown_server().unwrap();
        server.join();
        println!("{}", svc.report());
        drop(svc);
    }

    report
        .write("service", "BENCH_service.json")
        .expect("write BENCH_service.json");
    println!("\nwrote BENCH_service.json");
}
