//! Per-iteration learning cost: Picard vs KRK-Picard vs stochastic KRK —
//! the Table-2 companion. The paper (MATLAB, N = 10⁴): Picard 161.5 s,
//! KRK 8.9 s (18×), stochastic 1.2 s (134×). The *ratios* are the claim
//! under test; sweep N to show the widening gap.
//!
//! The dense-Θ-vs-engine section measures the compressed-statistics
//! refactor head-to-head: the *literal pre-engine step* — two
//! `theta_dense` builds (no dedup, every duplicate subset factored
//! again) feeding `update_l{1,2}_from_theta`, exactly what
//! `KrkPicard::step` used to do — against the Θ-free `O(nκ²)` engine
//! sweep, at duplicate ratios 1× and 8× (dedup collapses repeats into
//! multiplicity weights, so the engine's sweep cost stays ~flat along
//! the dup axis while the dense path scales with raw `n`). Speedups land
//! in `BENCH_learning.json`, uploaded by the CI bench-smoke job next to
//! `BENCH_linalg.json` — see EXPERIMENTS.md §Learning.
//!
//! Knobs: `KRONDPP_BENCH_BUDGET_MS` (per-case budget),
//! `KRONDPP_BENCH_MAX_N` (skip cases above this ground-set size).

use krondpp::bench_util::{section, Bencher, Report};
use krondpp::data;
use krondpp::dpp::likelihood::theta_dense;
use krondpp::learn::{init, KrkPicard, KrkStochastic, Learner, Picard, TrainingSet};
use krondpp::rng::Rng;

fn max_n() -> usize {
    krondpp::bench_util::bench_max_n()
}

fn main() {
    let b = Bencher { min_iters: 2, ..Default::default() };
    let cap = max_n();
    let mut report = Report::new();

    section("dense-Θ vs compressed engine (KRK batch step)");
    for (n1, n2) in [(16usize, 16usize), (32, 32)] {
        let n = n1 * n2;
        if n > cap {
            println!("  (skipped N={n}: KRONDPP_BENCH_MAX_N)");
            continue;
        }
        for dup in [1usize, 8] {
            let mut rng = Rng::new(100 + (n + dup) as u64);
            let truth = data::paper_truth_kernel(n1, n2, &mut rng);
            let base =
                data::sample_training_set(&truth, 50, (n / 50).max(3), (n / 8).max(6), &mut rng)
                    .unwrap();
            let mut subsets = Vec::new();
            for _ in 0..dup {
                subsets.extend(base.subsets.iter().cloned());
            }
            let data_set = TrainingSet::new(n, subsets).unwrap();
            let l1 = init::paper_subkernel(n1, &mut rng);
            let l2 = init::paper_subkernel(n2, &mut rng);

            let mut dense = KrkPicard::new(l1.clone(), l2.clone(), 1.0).unwrap();
            let ds = b.run(&format!("krk dense-Θ N={n} dup={dup}x"), || {
                // The pre-engine step, verbatim: dense Θ per half-update,
                // no dedup — every one of the n (not n_unique) subsets is
                // gathered, factored and scattered, twice.
                let theta = theta_dense(&dense.kernel(), &data_set.subsets).unwrap();
                dense.update_l1_from_theta(&theta).unwrap();
                let theta = theta_dense(&dense.kernel(), &data_set.subsets).unwrap();
                dense.update_l2_from_theta(&theta).unwrap();
            });
            let mut engine = KrkPicard::new(l1.clone(), l2.clone(), 1.0).unwrap();
            let es = b.run(&format!("krk engine  N={n} dup={dup}x"), || {
                engine.step(&data_set).unwrap();
            });
            let speedup = ds.secs() / es.secs();
            println!(
                "    -> engine {speedup:.1}x faster ({}×{} Θ never materialized; n={} → {} unique sweeps)",
                n,
                n,
                data_set.len(),
                data_set.len() / dup
            );
            report.case(&ds, &[("ground_n", n as f64), ("dup", dup as f64)]);
            report.case(&es, &[("ground_n", n as f64), ("dup", dup as f64)]);
            report.derived(&format!("engine_speedup_n{n}_dup{dup}"), speedup);
        }
    }
    report.write("learning", "BENCH_learning.json").expect("write BENCH_learning.json");
    println!("  report -> BENCH_learning.json");

    section("per-iteration cost (Table 2 shape)");
    println!(
        "{:<10} {:>12} {:>12} {:>14} {:>10} {:>12}",
        "N", "picard", "krk", "krk-stoch", "krk ×", "stoch ×"
    );
    for (n1, n2) in [(16usize, 16usize), (24, 24), (32, 32), (40, 40)] {
        let n = n1 * n2;
        if n > cap {
            println!("  (skipped N={n}: KRONDPP_BENCH_MAX_N)");
            continue;
        }
        let mut rng = Rng::new(7 + n as u64);
        let truth = data::paper_truth_kernel(n1, n2, &mut rng);
        let data =
            data::sample_training_set(&truth, 50, (n / 50).max(3), (n / 8).max(6), &mut rng)
                .unwrap();
        let l1 = init::paper_subkernel(n1, &mut rng);
        let l2 = init::paper_subkernel(n2, &mut rng);

        let mut krk = KrkPicard::new(l1.clone(), l2.clone(), 1.0).unwrap();
        let krk_stats = b.run(&format!("krk-picard N={n}"), || {
            krk.step(&data).unwrap();
        });

        let mut stoch = KrkStochastic::new(l1.clone(), l2.clone(), 0.7, 1, 3);
        let stoch_stats = b.run(&format!("krk-stochastic N={n}"), || {
            stoch.step(&data).unwrap();
        });

        let mut picard = Picard::new(krondpp::linalg::kron::kron(&l1, &l2), 1.0).unwrap();
        let pic_stats = b.run(&format!("picard N={n}"), || {
            picard.step(&data).unwrap();
        });

        println!(
            "{:<10} {:>10.1}ms {:>10.1}ms {:>12.2}ms {:>9.1}x {:>11.1}x",
            n,
            pic_stats.secs() * 1e3,
            krk_stats.secs() * 1e3,
            stoch_stats.secs() * 1e3,
            pic_stats.secs() / krk_stats.secs(),
            pic_stats.secs() / stoch_stats.secs(),
        );
    }

    section("EM baseline per-iteration (Table-1 scale, N=64)");
    if 64 <= cap {
        let mut rng = Rng::new(5);
        let cat =
            krondpp::data::registry::generate_category("bench", 64, 150, 0, &mut rng).unwrap();
        let k0 = init::wishart_marginal(64, &mut rng).unwrap();
        let mut em = krondpp::learn::EmLearner::from_marginal(&k0).unwrap();
        b.run("em N=64 n=150", || {
            em.step(&cat.train).unwrap();
        });
    }

    section("stochastic update: KRK vs low-rank [9] (§3.1.2 claim)");
    {
        let (n1, n2) = (32usize, 32usize);
        let n = n1 * n2;
        if n <= cap {
            let mut rng = Rng::new(11);
            let truth = data::paper_truth_kernel(n1, n2, &mut rng);
            let data = data::sample_training_set(&truth, 60, 8, 40, &mut rng).unwrap();
            let kappa = data.kappa();
            let l1 = init::paper_subkernel(n1, &mut rng);
            let l2 = init::paper_subkernel(n2, &mut rng);
            let mut krk = KrkStochastic::new(l1, l2, 0.7, 1, 13);
            let krk_stats = b.run(&format!("krk stochastic update N={n}"), || {
                krk.step(&data).unwrap();
            });
            // Low-rank with K = 2κ (needs K ≥ κ to score the data at all).
            let mut lowrank = krondpp::learn::LowRank::init(n, 2 * kappa, 0.02, 17);
            lowrank.minibatch = 1;
            let lr_stats =
                b.run(&format!("lowrank stochastic update N={n} K={}", 2 * kappa), || {
                    lowrank.step(&data).unwrap();
                });
            println!(
                "    -> krk stochastic is {:.1}x faster per update (and has no rank ceiling)",
                lr_stats.secs() / krk_stats.secs()
            );
        } else {
            println!("  (skipped N={n}: KRONDPP_BENCH_MAX_N)");
        }
    }

    section("joint-picard per-iteration (Fig-1 scale)");
    {
        let (n1, n2) = (24usize, 24usize);
        if n1 * n2 <= cap {
            let mut rng = Rng::new(9);
            let truth = data::paper_truth_kernel(n1, n2, &mut rng);
            let data = data::sample_training_set(&truth, 40, 6, 60, &mut rng).unwrap();
            let mut joint = krondpp::learn::JointPicard::new(
                init::paper_subkernel(n1, &mut rng),
                init::paper_subkernel(n2, &mut rng),
                1.0,
            )
            .unwrap();
            b.run(&format!("joint-picard N={}", n1 * n2), || {
                joint.step(&data).unwrap();
            });
        } else {
            println!("  (skipped N={}: KRONDPP_BENCH_MAX_N)", n1 * n2);
        }
    }
}
