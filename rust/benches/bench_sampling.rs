//! §4 sampling claims: exact-sampling preprocessing is O(N³) for a dense
//! kernel vs O(N^{3/2}) for Kron2 vs ~O(N) for Kron3; per-draw cost is
//! O(Nk³)-ish for all. The crossover table shows who wins where.
//!
//! The batched-engine section compares the three per-draw regimes at
//! N = 1024: fresh-scratch sequential draws, scratch-reuse sequential
//! draws (1 thread), and `sample_batch` fanned across all threads —
//! the multi-threaded row is the serving stack's hot path.

use krondpp::bench_util::{black_box, section, Bencher};
use krondpp::data;
use krondpp::dpp::{Kernel, Sampler};
use krondpp::linalg::matmul::available_threads;
use krondpp::rng::Rng;

fn main() {
    let b = Bencher { min_iters: 2, ..Default::default() };

    section("eigendecomposition preprocessing: dense vs Kron2 vs Kron3");
    println!("{:<8} {:>14} {:>14} {:>14}", "N", "full", "kron2", "kron3");
    for &n_target in &[256usize, 1024, 2304] {
        let mut rng = Rng::new(n_target as u64);
        // Kron2: n1 = n2 = sqrt(N); Kron3: cube-root factors.
        let s2 = (n_target as f64).sqrt() as usize;
        let s3 = (n_target as f64).cbrt().round() as usize;
        let kron2 = data::paper_truth_kernel(s2, s2, &mut rng);
        let k3a = krondpp::learn::init::paper_subkernel(s3, &mut rng);
        let k3b = krondpp::learn::init::paper_subkernel(s3, &mut rng);
        let k3c = krondpp::learn::init::paper_subkernel(s3, &mut rng);
        let kron3 = Kernel::Kron3(k3a, k3b, k3c);

        let t_kron2 = b
            .run(&format!("kron2 eigen N={}", s2 * s2), || {
                black_box(Sampler::new(&kron2).unwrap());
            })
            .secs();
        let t_kron3 = b
            .run(&format!("kron3 eigen N={}", s3 * s3 * s3), || {
                black_box(Sampler::new(&kron3).unwrap());
            })
            .secs();
        // Dense eigen is the expensive one (221 s at N=2304 on this box;
        // see EXPERIMENTS.md): above 1024 it only runs with
        // KRONDPP_BENCH_FULL=1 so a default `cargo bench` stays tractable.
        if n_target > 1024 && std::env::var("KRONDPP_BENCH_FULL").is_err() {
            println!(
                "{:<8} {:>12}ms {:>12.1}ms {:>12.1}ms   (dense skipped; KRONDPP_BENCH_FULL=1 to run)",
                s2 * s2,
                "-",
                t_kron2 * 1e3,
                t_kron3 * 1e3
            );
            continue;
        }
        let full = Kernel::Full(kron2.to_dense());
        let t_full = if n_target <= 1024 {
            b.run(&format!("full eigen N={}", s2 * s2), || {
                black_box(Sampler::new(&full).unwrap());
            })
            .secs()
        } else {
            let s = b.run_once(&format!("full eigen N={} (once)", s2 * s2), || {
                black_box(Sampler::new(&full).unwrap());
            });
            s.secs()
        };
        println!(
            "{:<8} {:>12.1}ms {:>12.1}ms {:>12.1}ms   (full/kron2 = {:.0}x)",
            s2 * s2,
            t_full * 1e3,
            t_kron2 * 1e3,
            t_kron3 * 1e3,
            t_full / t_kron2
        );
    }

    section("per-draw cost after preprocessing (shared across structures)");
    {
        let mut rng = Rng::new(77);
        let kernel = data::paper_truth_kernel(32, 32, &mut rng);
        let sampler = Sampler::new(&kernel).unwrap();
        for k in [5usize, 10, 20, 40] {
            let mut draw_rng = Rng::new(5);
            b.run(&format!("sample_k k={k} (N=1024)"), || {
                black_box(sampler.sample_k(k, &mut draw_rng));
            });
        }
        let mut draw_rng = Rng::new(6);
        b.run("sample (unconstrained, N=1024)", || {
            black_box(sampler.sample(&mut draw_rng));
        });
    }

    section("batched engine (N=1024): sequential vs scratch-reuse vs threads");
    {
        let mut rng = Rng::new(99);
        let kernel = data::paper_truth_kernel(32, 32, &mut rng);
        let sampler = Sampler::new(&kernel).unwrap();
        let nthreads = available_threads();
        for &(draws, k) in &[(64usize, Some(10usize)), (64, None)] {
            let label = match k {
                Some(k) => format!("k={k}"),
                None => "unconstrained".into(),
            };
            let t_fresh = b
                .run(&format!("{draws} draws, fresh scratch each ({label})"), || {
                    let mut r = Rng::new(5);
                    for _ in 0..draws {
                        match k {
                            Some(k) => {
                                black_box(sampler.sample_k(k, &mut r));
                            }
                            None => {
                                black_box(sampler.sample(&mut r));
                            }
                        }
                    }
                })
                .secs();
            let t_seq = b
                .run(&format!("{draws} draws, batch on 1 thread ({label})"), || {
                    black_box(sampler.sample_batch_threads(draws, k, 7, 1));
                })
                .secs();
            let t_par = b
                .run(&format!("{draws} draws, batch on {nthreads} threads ({label})"), || {
                    black_box(sampler.sample_batch(draws, k, 7));
                })
                .secs();
            println!(
                "  {label}: {:.0} draws/s sequential, {:.0} draws/s scratch-reuse, \
                 {:.0} draws/s batched ({:.1}x vs sequential, {:.1}x vs scratch-reuse)",
                draws as f64 / t_fresh,
                draws as f64 / t_seq,
                draws as f64 / t_par,
                t_fresh / t_par,
                t_seq / t_par,
            );
        }
    }

    section("MCMC baseline: cost per effective sample (burn 2N steps)");
    {
        let mut rng = Rng::new(88);
        let kernel = data::paper_truth_kernel(16, 16, &mut rng);
        let mut chain_rng = Rng::new(7);
        b.run("mcmc 512 steps (N=256)", || {
            let mut chain = krondpp::dpp::mcmc::McmcSampler::new(&kernel);
            black_box(chain.run(512, &mut chain_rng).unwrap());
        });
    }
}
