//! Coordinator integration under load and failure injection: concurrent
//! clients, hot-swaps mid-flight, backpressure accounting, and
//! metrics-vs-observed consistency.

use krondpp::config::ServiceConfig;
use krondpp::coordinator::{DppService, LearningJob, SampleRequest};
use krondpp::data;
use krondpp::learn::init;
use krondpp::rng::Rng;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;

fn kernel(n1: usize, n2: usize, seed: u64) -> krondpp::dpp::Kernel {
    let mut rng = Rng::new(seed);
    data::paper_truth_kernel(n1, n2, &mut rng)
}

#[test]
fn many_clients_with_live_hot_swaps() {
    let cfg = ServiceConfig {
        workers: 4,
        max_batch: 16,
        batch_window_us: 100,
        queue_capacity: 50_000,
    };
    let svc = Arc::new(DppService::start(&kernel(4, 4, 1), &cfg, 2).unwrap());
    let done = Arc::new(AtomicUsize::new(0));
    let mut handles = Vec::new();
    // 6 client threads × 50 requests.
    for t in 0..6u64 {
        let svc2 = Arc::clone(&svc);
        let done2 = Arc::clone(&done);
        handles.push(std::thread::spawn(move || {
            for i in 0..50usize {
                let k = (t as usize + i) % 5 + 1;
                let y = svc2.sample(k).expect("sample failed");
                assert_eq!(y.len(), k);
                assert!(y.iter().all(|&item| item < 16));
                done2.fetch_add(1, Ordering::SeqCst);
            }
        }));
    }
    // Swapper thread: replaces the kernel (same N) 10 times mid-flight.
    {
        let svc2 = Arc::clone(&svc);
        handles.push(std::thread::spawn(move || {
            for s in 0..10u64 {
                svc2.update_kernel(&kernel(4, 4, 100 + s)).unwrap();
                std::thread::sleep(std::time::Duration::from_millis(2));
            }
        }));
    }
    for h in handles {
        h.join().unwrap();
    }
    assert_eq!(done.load(Ordering::SeqCst), 300);
    let m = svc.metrics();
    assert_eq!(m.completed.load(Ordering::Relaxed), m.accepted.load(Ordering::Relaxed));
}

#[test]
fn backpressure_accounting_exact() {
    let cfg = ServiceConfig {
        workers: 1,
        max_batch: 1,
        batch_window_us: 0,
        queue_capacity: 4,
    };
    let svc = DppService::start(&kernel(3, 3, 3), &cfg, 4).unwrap();
    let mut accepted = 0u64;
    let mut rejected = 0u64;
    let mut tickets = Vec::new();
    for _ in 0..500 {
        match svc.submit(SampleRequest { k: 2 }) {
            Ok(t) => {
                accepted += 1;
                tickets.push(t);
            }
            Err(_) => rejected += 1,
        }
    }
    for t in tickets {
        t.wait().unwrap();
    }
    let m = svc.metrics();
    assert_eq!(m.accepted.load(Ordering::Relaxed), accepted);
    assert_eq!(m.rejected.load(Ordering::Relaxed), rejected);
    assert_eq!(m.completed.load(Ordering::Relaxed), accepted);
    svc.shutdown();
}

#[test]
fn learning_job_and_serving_share_the_system() {
    let cfg = ServiceConfig {
        workers: 2,
        max_batch: 8,
        batch_window_us: 100,
        queue_capacity: 10_000,
    };
    let truth = kernel(3, 3, 5);
    let svc = Arc::new(DppService::start(&truth, &cfg, 6).unwrap());
    let mut rng = Rng::new(7);
    let train = data::sample_training_set(&truth, 30, 2, 6, &mut rng).unwrap();
    let learner = krondpp::learn::KrkPicard::new(
        init::paper_subkernel(3, &mut rng),
        init::paper_subkernel(3, &mut rng),
        1.0,
    )
    .unwrap();
    let job = LearningJob::spawn(Box::new(learner), train, 6, 0.0, Some(Arc::clone(&svc)));
    // Keep serving while learning runs.
    let mut served = 0;
    for _ in 0..60 {
        if svc.sample(3).is_ok() {
            served += 1;
        }
    }
    let history = job.join().unwrap();
    assert_eq!(served, 60);
    assert!(history.len() >= 2);
    // Progress is monotone for a=1 (Thm 3.2) even while serving.
    for w in history.windows(2) {
        assert!(w[1].log_likelihood >= w[0].log_likelihood - 1e-9);
    }
}

#[test]
fn service_rng_streams_give_distinct_samples() {
    // Two workers must not produce identical sample streams (stream split).
    let cfg = ServiceConfig {
        workers: 2,
        max_batch: 1,
        batch_window_us: 0,
        queue_capacity: 10_000,
    };
    let svc = DppService::start(&kernel(4, 4, 8), &cfg, 9).unwrap();
    let mut samples = Vec::new();
    for _ in 0..40 {
        samples.push(svc.sample(4).unwrap());
    }
    let distinct: std::collections::BTreeSet<_> = samples.iter().collect();
    assert!(distinct.len() > 10, "suspiciously repetitive samples: {}", distinct.len());
    svc.shutdown();
}
