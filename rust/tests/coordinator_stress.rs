//! Coordinator integration under load and failure injection: concurrent
//! clients, multi-tenant epoch hot-swaps mid-flight, mixed sampler-mode
//! traffic, LRU eviction + lazy rebuild round-trips, backpressure
//! accounting, and metrics-vs-observed consistency.

use krondpp::config::ServiceConfig;
use krondpp::coordinator::{DppService, LearningJob, SampleRequest};
use krondpp::data;
use krondpp::dpp::{Constraint, SampleMode};
use krondpp::learn::init;
use krondpp::rng::Rng;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::Arc;

fn kernel(n1: usize, n2: usize, seed: u64) -> krondpp::dpp::Kernel {
    let mut rng = Rng::new(seed);
    data::paper_truth_kernel(n1, n2, &mut rng)
}

#[test]
fn many_clients_with_live_hot_swaps() {
    let cfg = ServiceConfig {
        workers: 4,
        max_batch: 16,
        batch_window_us: 100,
        queue_capacity: 50_000,
        ..ServiceConfig::default()
    };
    let svc = Arc::new(DppService::start(&kernel(4, 4, 1), &cfg, 2).unwrap());
    let done = Arc::new(AtomicUsize::new(0));
    let mut handles = Vec::new();
    // 6 client threads × 50 requests.
    for t in 0..6u64 {
        let svc2 = Arc::clone(&svc);
        let done2 = Arc::clone(&done);
        handles.push(std::thread::spawn(move || {
            for i in 0..50usize {
                let k = (t as usize + i) % 5 + 1;
                let y = svc2.sample(k).expect("sample failed");
                assert_eq!(y.len(), k);
                assert!(y.iter().all(|&item| item < 16));
                done2.fetch_add(1, Ordering::SeqCst);
            }
        }));
    }
    // Swapper thread: replaces the kernel (same N) 10 times mid-flight.
    {
        let svc2 = Arc::clone(&svc);
        handles.push(std::thread::spawn(move || {
            for s in 0..10u64 {
                svc2.update_kernel(&kernel(4, 4, 100 + s)).unwrap();
                std::thread::sleep(std::time::Duration::from_millis(2));
            }
        }));
    }
    for h in handles {
        h.join().unwrap();
    }
    assert_eq!(done.load(Ordering::SeqCst), 300);
    let m = svc.metrics();
    assert_eq!(m.completed.load(Ordering::Relaxed), m.accepted.load(Ordering::Relaxed));
}

/// Constrained requests under concurrent hot swaps: every accepted
/// conditioned request either completes honoring its constraint or is
/// late-rejected by a shrinking publish — never silently mis-served. The
/// metric invariant accepted = completed + failed + rejected_invalid must
/// hold with conditioning in the mix, and same-context requests must
/// share conditioning setups.
#[test]
fn constrained_requests_survive_hot_swaps() {
    let cfg = ServiceConfig {
        workers: 2,
        max_batch: 16,
        batch_window_us: 200,
        queue_capacity: 50_000,
        ..ServiceConfig::default()
    };
    // N stays 16 across swaps so constraints remain in-bounds; the
    // kernels (and thus conditional laws) change under the clients.
    let svc = Arc::new(DppService::start(&kernel(4, 4, 40), &cfg, 41).unwrap());
    let mut handles = Vec::new();
    let completed = Arc::new(AtomicUsize::new(0));
    for t in 0..4u64 {
        let svc2 = Arc::clone(&svc);
        let completed2 = Arc::clone(&completed);
        handles.push(std::thread::spawn(move || {
            // Two alternating slate contexts per thread → heavy reuse.
            let contexts = [
                Constraint::new(vec![t as usize], vec![15]).unwrap(),
                Constraint::new(vec![t as usize, 8], vec![14]).unwrap(),
            ];
            for i in 0..40usize {
                let c = contexts[i % 2].clone();
                let k = 4 + i % 3;
                match svc2
                    .submit(SampleRequest::new(k).with_constraint(c.clone()))
                    .unwrap()
                    .wait()
                {
                    Ok(y) => {
                        assert_eq!(y.len(), k);
                        for inc in c.include() {
                            assert!(y.contains(inc), "include {inc} missing: {y:?}");
                        }
                        for exc in c.exclude() {
                            assert!(!y.contains(exc), "exclude {exc} present: {y:?}");
                        }
                        assert!(y.iter().all(|&item| item < 16));
                        completed2.fetch_add(1, Ordering::SeqCst);
                    }
                    Err(krondpp::Error::Rejected(_)) => {} // shrink race
                    Err(e) => panic!("conditioned request failed: {e}"),
                }
            }
        }));
    }
    {
        let svc2 = Arc::clone(&svc);
        handles.push(std::thread::spawn(move || {
            for s in 0..8u64 {
                svc2.update_kernel(&kernel(4, 4, 200 + s)).unwrap();
                std::thread::sleep(std::time::Duration::from_millis(2));
            }
        }));
    }
    for h in handles {
        h.join().unwrap();
    }
    let m = svc.metrics();
    let accepted = m.accepted.load(Ordering::Relaxed);
    let done = m.completed.load(Ordering::Relaxed)
        + m.failed.load(Ordering::Relaxed)
        + m.rejected_invalid.load(Ordering::Relaxed);
    assert_eq!(accepted, done, "accounting drifted under conditioning");
    assert_eq!(m.conditioned.load(Ordering::Relaxed) as usize, completed.load(Ordering::SeqCst));
    let setups = m.conditioning_setups.load(Ordering::Relaxed);
    assert!(setups > 0, "no conditioning setups recorded");
    assert!(
        setups <= m.conditioned.load(Ordering::Relaxed),
        "more setups than conditioned draws ({setups})"
    );
    // The marginals endpoint serves from whatever epoch is current.
    let probs = svc.marginals(krondpp::coordinator::TenantId::DEFAULT).unwrap();
    assert_eq!(probs.len(), 16);
    assert!(probs.iter().all(|p| (0.0..=1.0).contains(p)));
}

/// Mixed sampler-mode traffic under live hot swaps: four client threads
/// cycle through every [`SampleMode`] against one tenant while a swapper
/// republishes same-`N` kernels mid-flight. Every accepted request must
/// complete (same-`N` swaps can never invalidate a queued request), the
/// per-mode completion counters must match the client-side tallies
/// *exactly* (globally and per tenant), and the accounting invariant
/// `accepted = completed + failed + rejected_invalid` must hold with
/// `failed = 0`.
#[test]
fn mixed_mode_traffic_survives_hot_swaps_with_exact_mode_accounting() {
    let cfg = ServiceConfig {
        workers: 3,
        max_batch: 8,
        batch_window_us: 100,
        queue_capacity: 50_000,
        ..ServiceConfig::default()
    };
    let svc = Arc::new(DppService::start(&kernel(4, 4, 21), &cfg, 22).unwrap());
    let modes = [
        SampleMode::Exact,
        SampleMode::Mcmc { steps: 64 },
        SampleMode::LowRank { rank: 12 },
        SampleMode::Map,
    ];
    // Client-side success tallies, indexed like `modes`.
    let served: Arc<Vec<AtomicUsize>> =
        Arc::new((0..modes.len()).map(|_| AtomicUsize::new(0)).collect());
    let mut handles = Vec::new();
    for t in 0..4u64 {
        let svc2 = Arc::clone(&svc);
        let served2 = Arc::clone(&served);
        handles.push(std::thread::spawn(move || {
            for i in 0..48usize {
                let mi = (t as usize + i) % modes.len();
                let k = i % 5 + 1; // 1..=5, ≤ rank 12, valid for N = 16
                let y = svc2
                    .submit(SampleRequest::new(k).with_mode(modes[mi]))
                    .expect("admission refused a valid mode")
                    .wait()
                    .expect("accepted mixed-mode request failed");
                assert_eq!(y.len(), k, "mode {} returned wrong size", modes[mi].label());
                assert!(y.iter().all(|&item| item < 16));
                assert!(y.windows(2).all(|w| w[0] < w[1]), "unsorted slate: {y:?}");
                served2[mi].fetch_add(1, Ordering::SeqCst);
            }
        }));
    }
    // Swapper: same-N republishes so queued requests stay valid across
    // every generation they might race with.
    {
        let svc2 = Arc::clone(&svc);
        handles.push(std::thread::spawn(move || {
            for s in 0..10u64 {
                svc2.update_kernel(&kernel(4, 4, 400 + s)).unwrap();
                std::thread::sleep(std::time::Duration::from_millis(2));
            }
        }));
    }
    for h in handles {
        h.join().unwrap();
    }
    let m = svc.metrics();
    let accepted = m.accepted.load(Ordering::Relaxed);
    assert_eq!(accepted, 4 * 48);
    assert_eq!(m.completed.load(Ordering::Relaxed), accepted);
    assert_eq!(m.failed.load(Ordering::Relaxed), 0);
    assert_eq!(m.rejected_invalid.load(Ordering::Relaxed), 0);
    // Per-mode counters are exact — each mode family saw exactly the
    // requests the clients counted, globally and on the tenant.
    let reg = svc.registry();
    let tenant = reg.entry(svc.tenant("default").unwrap()).unwrap();
    for (mi, &mode) in modes.iter().enumerate() {
        let want = served[mi].load(Ordering::SeqCst) as u64;
        assert_eq!(want, 48, "client tally for {} off", mode.label());
        assert_eq!(
            m.modes.get(mode),
            want,
            "global per-mode counter drifted for {}",
            mode.label()
        );
        assert_eq!(
            tenant.metrics().modes.get(mode),
            want,
            "tenant per-mode counter drifted for {}",
            mode.label()
        );
    }
    assert!(m.report().contains("modes: exact=48 mcmc=48 lowrank=48 map=48"));
}

/// The tentpole's acceptance scenario: continuous submits across two
/// tenants while both tenants' epochs are republished (including
/// ground-set-size changes). Every accepted request must complete, with
/// indices valid for either the pre- or post-swap generation — and epoch
/// publication must not wedge readers (clients of the *other* tenant keep
/// completing while a publish's eigendecomposition runs).
#[test]
fn hot_swap_under_load_across_tenants() {
    let cfg = ServiceConfig {
        workers: 4,
        max_batch: 16,
        batch_window_us: 100,
        queue_capacity: 50_000,
        ..ServiceConfig::default()
    };
    // Tenant a alternates N ∈ {16, 9}; tenant b alternates N ∈ {12, 6}.
    // Clients request k ≤ 5, valid for every generation of both tenants.
    let svc = Arc::new(DppService::start(&kernel(4, 4, 1), &cfg, 3).unwrap());
    let a = svc.tenant("default").unwrap();
    let b = svc.add_tenant("b", &kernel(3, 4, 2)).unwrap();
    let stop = Arc::new(AtomicBool::new(false));
    let done = Arc::new(AtomicUsize::new(0));
    let mut handles = Vec::new();
    for t in 0..6u64 {
        let svc2 = Arc::clone(&svc);
        let done2 = Arc::clone(&done);
        handles.push(std::thread::spawn(move || {
            for i in 0..60usize {
                let (tenant, bound) = if (t as usize + i) % 2 == 0 { (a, 16) } else { (b, 12) };
                let k = (t as usize + i) % 5 + 1;
                let y = svc2.sample_tenant(tenant, k).expect("accepted request failed");
                assert_eq!(y.len(), k);
                assert!(
                    y.iter().all(|&item| item < bound),
                    "index out of both generations' bounds: {y:?} (tenant bound {bound})"
                );
                done2.fetch_add(1, Ordering::SeqCst);
            }
        }));
    }
    // Swapper: republish both tenants continuously until clients finish.
    let swapper = {
        let svc2 = Arc::clone(&svc);
        let stop2 = Arc::clone(&stop);
        std::thread::spawn(move || {
            let mut swaps = 0u64;
            while !stop2.load(Ordering::SeqCst) {
                let (na1, na2) = if swaps % 2 == 0 { (3, 3) } else { (4, 4) };
                let (nb1, nb2) = if swaps % 2 == 0 { (2, 3) } else { (3, 4) };
                svc2.publish(a, &kernel(na1, na2, 200 + swaps)).unwrap();
                svc2.publish(b, &kernel(nb1, nb2, 300 + swaps)).unwrap();
                swaps += 1;
            }
            swaps
        })
    };
    for h in handles {
        h.join().unwrap();
    }
    stop.store(true, Ordering::SeqCst);
    let swaps = swapper.join().unwrap();
    assert_eq!(done.load(Ordering::SeqCst), 360);
    assert!(swaps > 0, "swapper never ran");
    let m = svc.metrics();
    assert_eq!(m.completed.load(Ordering::Relaxed), m.accepted.load(Ordering::Relaxed));
    // Generations advanced on both tenants while serving.
    let reg = svc.registry();
    assert_eq!(reg.entry(a).unwrap().generation(), 1 + swaps);
    assert_eq!(reg.entry(b).unwrap().generation(), 1 + swaps);
    // Per-tenant accounting: both tenants saw traffic, and the per-tenant
    // completion counters sum to the global one.
    let ca = reg.entry(a).unwrap().metrics().completed.load(Ordering::Relaxed);
    let cb = reg.entry(b).unwrap().metrics().completed.load(Ordering::Relaxed);
    assert_eq!(ca, 180);
    assert_eq!(cb, 180);
}

/// LRU bound 1 with two live tenants: every request thrashes the resident
/// slot, so epochs are continually evicted and lazily rebuilt — and every
/// request still completes with valid indices and unchanged generations.
#[test]
fn eviction_and_lazy_rebuild_round_trips() {
    let cfg = ServiceConfig {
        workers: 2,
        max_batch: 4,
        batch_window_us: 50,
        queue_capacity: 10_000,
        max_resident_epochs: 1,
        ..ServiceConfig::default()
    };
    let svc = DppService::start(&kernel(3, 3, 5), &cfg, 6).unwrap();
    let a = svc.tenant("default").unwrap();
    let b = svc.add_tenant("b", &kernel(2, 3, 7)).unwrap();
    for i in 0..30usize {
        let (tenant, bound) = if i % 2 == 0 { (a, 9) } else { (b, 6) };
        let y = svc.sample_tenant(tenant, 2).unwrap();
        assert_eq!(y.len(), 2);
        assert!(y.iter().all(|&item| item < bound));
    }
    let reg = svc.registry();
    assert!(reg.resident_epochs() <= 1, "LRU bound violated");
    assert!(reg.evictions() > 0, "bound 1 with 2 tenants must evict");
    assert!(reg.rebuilds() > 0, "cold tenants must lazily rebuild");
    // Rebuilds must not masquerade as publishes: generation is untouched.
    assert_eq!(reg.entry(a).unwrap().generation(), 1);
    assert_eq!(reg.entry(b).unwrap().generation(), 1);
    svc.shutdown();
}

#[test]
fn backpressure_accounting_exact() {
    let cfg = ServiceConfig {
        workers: 1,
        max_batch: 1,
        batch_window_us: 0,
        queue_capacity: 4,
        ..ServiceConfig::default()
    };
    let svc = DppService::start(&kernel(3, 3, 3), &cfg, 4).unwrap();
    let mut accepted = 0u64;
    let mut rejected = 0u64;
    let mut tickets = Vec::new();
    for _ in 0..500 {
        match svc.submit(SampleRequest::new(2)) {
            Ok(t) => {
                accepted += 1;
                tickets.push(t);
            }
            Err(_) => rejected += 1,
        }
    }
    for t in tickets {
        t.wait().unwrap();
    }
    let m = svc.metrics();
    assert_eq!(m.accepted.load(Ordering::Relaxed), accepted);
    assert_eq!(m.rejected.load(Ordering::Relaxed), rejected);
    assert_eq!(m.completed.load(Ordering::Relaxed), accepted);
    // Backpressure is not admission rejection.
    assert_eq!(m.rejected_invalid.load(Ordering::Relaxed), 0);
    svc.shutdown();
}

/// Queue-depth shedding under concurrent submitters keeps the ledger
/// exact: every submit resolves to exactly one client-observed outcome,
/// `throttled` sheds burn **zero queue slots** (proven by `rejected == 0`
/// while the shed threshold sits far below `queue_capacity`), and the
/// worker-side ledger closes to accepted == completed + failed.
#[test]
fn queue_shed_ledger_exact_under_concurrency() {
    let cfg = ServiceConfig {
        workers: 1,
        max_batch: 64,
        batch_window_us: 50_000,
        queue_capacity: 4096,
        shed_queue_depth: 8,
        ..ServiceConfig::default()
    };
    let svc = std::sync::Arc::new(DppService::start(&kernel(3, 3, 5), &cfg, 6).unwrap());
    let mut handles = Vec::new();
    for t in 0..4u64 {
        let svc = std::sync::Arc::clone(&svc);
        handles.push(std::thread::spawn(move || {
            let mut ok = 0u64;
            let mut throttled = 0u64;
            let mut other = 0u64;
            let mut tickets = Vec::new();
            for _ in 0..100 {
                match svc.submit(SampleRequest::new(2)) {
                    Ok(ticket) => {
                        ok += 1;
                        tickets.push(ticket);
                    }
                    Err(e) if e.kind() == krondpp::error::ErrorKind::Throttled => {
                        assert!(e.is_retryable(), "shed must be retryable: {e}");
                        throttled += 1;
                    }
                    Err(_) => other += 1,
                }
                if t == 0 {
                    // One submitter yields so the pump occasionally wins
                    // the race and the accepted count stays interesting.
                    std::thread::yield_now();
                }
            }
            for ticket in tickets {
                ticket.wait().unwrap();
            }
            (ok, throttled, other)
        }));
    }
    let mut ok = 0u64;
    let mut throttled = 0u64;
    let mut other = 0u64;
    for h in handles {
        let (o, th, ot) = h.join().unwrap();
        ok += o;
        throttled += th;
        other += ot;
    }
    assert_eq!(ok + throttled + other, 400, "every submit observed exactly once");
    assert!(throttled > 0, "shed threshold 8 against a 50ms window must throttle");
    let m = svc.metrics();
    // Client-observed tallies match the service ledger exactly.
    assert_eq!(m.accepted.load(Ordering::Relaxed), ok);
    assert_eq!(m.throttled.load(Ordering::Relaxed), throttled);
    // Sheds happened at depth 8 of a 4096-slot queue: capacity was never
    // touched, so no backpressure rejections — throttles burned no slot.
    assert_eq!(m.rejected.load(Ordering::Relaxed), 0);
    assert_eq!(other, 0);
    // Worker-side ledger closes over accepted work only.
    assert_eq!(
        m.completed.load(Ordering::Relaxed) + m.failed.load(Ordering::Relaxed),
        ok
    );
    let entry = svc.registry().entry(krondpp::coordinator::TenantId::DEFAULT).unwrap();
    let tm = entry.metrics();
    assert_eq!(tm.accepted.load(Ordering::Relaxed), ok);
    assert_eq!(tm.throttled.load(Ordering::Relaxed), throttled);
    assert_eq!(entry.outstanding(), 0, "all accepted work settled");
}

/// Shutdown under load is a drain, not a drop: a burst submitted just
/// before `shutdown()` (most of it still queued behind a long batch
/// window) must still resolve — the pump flushes the queue to the
/// workers, each worker finishes its channel backlog before exiting,
/// and every ticket yields a definitive outcome after the service is
/// gone. The per-tenant ledger must close to accepted == completed.
#[test]
fn shutdown_under_load_drains_every_ticket() {
    let cfg = ServiceConfig {
        workers: 2,
        max_batch: 4,
        batch_window_us: 50_000,
        queue_capacity: 4096,
        ..ServiceConfig::default()
    };
    let svc = DppService::start(&kernel(3, 3, 77), &cfg, 78).unwrap();
    let registry = std::sync::Arc::clone(svc.registry());
    let mut tickets = Vec::new();
    for i in 0..200usize {
        tickets.push(svc.submit(SampleRequest::new(1 + i % 4)).unwrap());
    }
    svc.shutdown();
    // Tickets outlive the service: responses were buffered before the
    // workers exited, so every wait() resolves immediately.
    for (i, t) in tickets.into_iter().enumerate() {
        let y = t.wait().unwrap_or_else(|e| panic!("ticket {i} dangled across shutdown: {e}"));
        assert_eq!(y.len(), 1 + i % 4);
        assert!(y.iter().all(|&item| item < 9));
    }
    let entry = registry.entry(krondpp::coordinator::TenantId::DEFAULT).unwrap();
    let tm = entry.metrics();
    assert_eq!(tm.accepted.load(Ordering::Relaxed), 200);
    assert_eq!(tm.completed.load(Ordering::Relaxed), 200);
    assert_eq!(tm.failed.load(Ordering::Relaxed), 0);
}

/// Submitters racing `begin_shutdown()`: admission flips to refusal
/// mid-stream, every ticket accepted before the flip still resolves
/// definitively, post-shutdown submits get `Error::Service`, and the
/// per-tenant ledger reconciles with zero in-flight work at the end.
#[test]
fn racing_submitters_observe_clean_shutdown() {
    let cfg = ServiceConfig {
        workers: 2,
        max_batch: 8,
        batch_window_us: 500,
        queue_capacity: 100_000,
        ..ServiceConfig::default()
    };
    let svc = Arc::new(DppService::start(&kernel(3, 3, 81), &cfg, 82).unwrap());
    let registry = Arc::clone(svc.registry());
    let accepted = Arc::new(AtomicUsize::new(0));
    let mut handles = Vec::new();
    for t in 0..4u64 {
        let svc2 = Arc::clone(&svc);
        let accepted2 = Arc::clone(&accepted);
        handles.push(std::thread::spawn(move || {
            let mut tickets = Vec::new();
            loop {
                match svc2.submit(SampleRequest::new(1 + t as usize % 3)) {
                    Ok(tk) => {
                        accepted2.fetch_add(1, Ordering::SeqCst);
                        tickets.push(tk);
                    }
                    Err(krondpp::Error::Service(m)) if m.contains("queue full") => {
                        std::thread::yield_now(); // backpressure, not shutdown
                    }
                    Err(krondpp::Error::Service(m)) => {
                        assert!(m.contains("shut down"), "unexpected refusal: {m}");
                        break;
                    }
                    Err(e) => panic!("unexpected admission error: {e}"),
                }
            }
            // Everything accepted before the flip must still resolve.
            for tk in tickets {
                tk.wait().expect("accepted request must complete across shutdown");
            }
        }));
    }
    std::thread::sleep(std::time::Duration::from_millis(10));
    svc.begin_shutdown();
    for h in handles {
        h.join().unwrap();
    }
    let n_accepted = accepted.load(Ordering::SeqCst) as u64;
    let entry = registry.entry(krondpp::coordinator::TenantId::DEFAULT).unwrap();
    let tm = entry.metrics();
    assert_eq!(tm.accepted.load(Ordering::Relaxed), n_accepted);
    assert_eq!(tm.completed.load(Ordering::Relaxed), n_accepted);
    assert_eq!(tm.failed.load(Ordering::Relaxed), 0);
    assert_eq!(svc.in_flight(), 0);
    assert_eq!(svc.tenant_in_flight(krondpp::coordinator::TenantId::DEFAULT), 0);
    // Post-shutdown submits are refused with a definitive error.
    match svc.submit(SampleRequest::new(2)) {
        Err(krondpp::Error::Service(m)) => assert!(m.contains("shut down"), "{m}"),
        Err(e) => panic!("wrong refusal class: {e}"),
        Ok(_) => panic!("post-shutdown submit must be refused"),
    }
    // The blocking join must return promptly (drain already happened).
    match Arc::try_unwrap(svc) {
        Ok(s) => s.shutdown(),
        Err(_) => panic!("service still shared after clients joined"),
    }
}

#[test]
fn invalid_requests_fail_fast_without_queue_slots() {
    let cfg = ServiceConfig {
        workers: 1,
        max_batch: 4,
        batch_window_us: 100,
        queue_capacity: 16,
        ..ServiceConfig::default()
    };
    let svc = DppService::start(&kernel(2, 2, 9), &cfg, 10).unwrap();
    // k > N: distinct error class, counted as invalid, never queued.
    for _ in 0..5 {
        match svc.sample(100) {
            Err(krondpp::Error::Rejected(_)) => {}
            other => panic!("expected Rejected, got {other:?}"),
        }
    }
    let m = svc.metrics();
    assert_eq!(m.rejected_invalid.load(Ordering::Relaxed), 5);
    assert_eq!(m.accepted.load(Ordering::Relaxed), 0);
    assert_eq!(m.rejected.load(Ordering::Relaxed), 0);
    // Valid work still flows afterwards.
    assert_eq!(svc.sample(3).unwrap().len(), 3);
    svc.shutdown();
}

#[test]
fn learning_job_and_serving_share_the_system() {
    let cfg = ServiceConfig {
        workers: 2,
        max_batch: 8,
        batch_window_us: 100,
        queue_capacity: 10_000,
        ..ServiceConfig::default()
    };
    let truth = kernel(3, 3, 5);
    let svc = Arc::new(DppService::start(&truth, &cfg, 6).unwrap());
    let mut rng = Rng::new(7);
    let train = data::sample_training_set(&truth, 30, 2, 6, &mut rng).unwrap();
    let learner = krondpp::learn::KrkPicard::new(
        init::paper_subkernel(3, &mut rng),
        init::paper_subkernel(3, &mut rng),
        1.0,
    )
    .unwrap();
    let job = LearningJob::spawn(Box::new(learner), train, 6, 0.0, Some(Arc::clone(&svc)))
        .unwrap();
    // Keep serving while learning runs.
    let mut served = 0;
    for _ in 0..60 {
        if svc.sample(3).is_ok() {
            served += 1;
        }
    }
    let history = job.join().unwrap();
    assert_eq!(served, 60);
    assert!(history.len() >= 2);
    // Progress is monotone for a=1 (Thm 3.2) even while serving.
    for w in history.windows(2) {
        assert!(w[1].log_likelihood >= w[0].log_likelihood - 1e-9);
    }
}

#[test]
fn service_rng_streams_give_distinct_samples() {
    // Two workers must not produce identical sample streams (stream split).
    let cfg = ServiceConfig {
        workers: 2,
        max_batch: 1,
        batch_window_us: 0,
        queue_capacity: 10_000,
        ..ServiceConfig::default()
    };
    let svc = DppService::start(&kernel(4, 4, 8), &cfg, 9).unwrap();
    let mut samples = Vec::new();
    for _ in 0..40 {
        samples.push(svc.sample(4).unwrap());
    }
    let distinct: std::collections::BTreeSet<_> = samples.iter().collect();
    assert!(distinct.len() > 10, "suspiciously repetitive samples: {}", distinct.len());
    svc.shutdown();
}
