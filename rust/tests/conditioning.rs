//! Conditioning correctness against a brute-force enumeration oracle.
//!
//! On small ground sets we can enumerate every subset, weight it by
//! `det(L_Y)`, restrict to the subsets satisfying `A ⊆ Y, B ∩ Y = ∅`
//! (and `|Y| = k` for the k-DPP variants) and renormalize — the exact
//! conditional law. `ConditionedSampler` empirical frequencies must match
//! it within sampling error, for m = 2 and m = 3, including `A = ∅`,
//! `B = ∅`, and the unconstrained case; overlapping constraints must be
//! rejected outright. The factored marginal queries
//! (`inclusion_probabilities_into`, `marginal_entry`) must agree with the
//! dense `marginal_kernel` oracle to ≤ 1e-12 on these sizes — that pair
//! of checks is the PR's acceptance criterion.

use std::collections::HashMap;

use krondpp::dpp::{ConditionedSampler, Constraint, Kernel, MarginalScratch, SampleScratch};
use krondpp::linalg::{lu, Matrix};
use krondpp::rng::Rng;

fn spd(n: usize, seed: u64) -> Matrix {
    let mut rng = Rng::new(seed);
    let mut m = rng.paper_init_kernel(n);
    m.scale_mut(1.5 / n as f64);
    m.add_diag_mut(0.3);
    m
}

/// Exact conditional subset probabilities by full enumeration:
/// `P(Y | A ⊆ Y, B ∩ Y = ∅ [, |Y| = k]) ∝ det(L_Y)`.
fn oracle(
    kernel: &Kernel,
    constraint: &Constraint,
    k: Option<usize>,
) -> HashMap<Vec<usize>, f64> {
    let n = kernel.n();
    assert!(n <= 12, "oracle is exponential in N");
    let amask: u64 = constraint.include().iter().map(|&i| 1u64 << i).sum();
    let bmask: u64 = constraint.exclude().iter().map(|&i| 1u64 << i).sum();
    let mut probs = HashMap::new();
    let mut total = 0.0;
    for bits in 0u64..(1u64 << n) {
        if bits & amask != amask || bits & bmask != 0 {
            continue;
        }
        let y: Vec<usize> = (0..n).filter(|&i| bits >> i & 1 == 1).collect();
        if let Some(k) = k {
            if y.len() != k {
                continue;
            }
        }
        let w = if y.is_empty() {
            1.0
        } else {
            lu::det(&kernel.principal_submatrix(&y)).unwrap()
        };
        assert!(w >= -1e-12, "det(L_Y) negative: {w}");
        total += w;
        probs.insert(y, w);
    }
    assert!(total > 0.0, "constraint admits no subsets");
    for v in probs.values_mut() {
        *v /= total;
    }
    probs
}

/// Draw `draws` samples and compare per-subset empirical frequencies with
/// the oracle at six standard errors (+ a small absolute floor).
fn check_against_oracle(
    kernel: &Kernel,
    constraint: Constraint,
    k: Option<usize>,
    draws: usize,
    seed: u64,
) {
    let probs = oracle(kernel, &constraint, k);
    let cs = ConditionedSampler::new(kernel, constraint.clone()).unwrap();
    let mut rng = Rng::new(seed);
    let mut scratch = SampleScratch::new();
    let mut counts: HashMap<Vec<usize>, usize> = HashMap::new();
    let mut out = Vec::new();
    for _ in 0..draws {
        match k {
            None => cs.sample_into(&mut rng, &mut scratch, &mut out),
            Some(k) => cs.sample_k_into(k, &mut rng, &mut scratch, &mut out),
        }
        *counts.entry(out.clone()).or_default() += 1;
    }
    // Every drawn subset must be oracle-admissible (constraint satisfied).
    for y in counts.keys() {
        assert!(
            probs.contains_key(y),
            "sampler produced inadmissible subset {y:?} under {constraint:?} (k={k:?})"
        );
    }
    for (y, &p) in &probs {
        let emp = counts.get(y).copied().unwrap_or(0) as f64 / draws as f64;
        let se = (p * (1.0 - p) / draws as f64).sqrt();
        assert!(
            (emp - p).abs() < 6.0 * se + 0.01,
            "subset {y:?}: empirical {emp:.4} vs oracle {p:.4} (k={k:?})"
        );
    }
}

fn kron2() -> Kernel {
    Kernel::Kron2(spd(3, 1), spd(3, 2))
}

fn kron3() -> Kernel {
    Kernel::Kron3(spd(2, 3), spd(2, 4), spd(2, 5))
}

#[test]
fn m2_conditioned_sampling_matches_enumeration() {
    let kernel = kron2();
    let c = Constraint::new(vec![2], vec![4, 7]).unwrap();
    check_against_oracle(&kernel, c, None, 40_000, 11);
}

#[test]
fn m2_conditioned_k_dpp_matches_enumeration() {
    let kernel = kron2();
    let c = Constraint::new(vec![2], vec![4, 7]).unwrap();
    check_against_oracle(&kernel, c, Some(3), 40_000, 13);
}

#[test]
fn m2_exclude_only_and_include_only_match_enumeration() {
    let kernel = kron2();
    // A = ∅ (pure ground-set restriction).
    check_against_oracle(&kernel, Constraint::excluding(vec![0, 5]).unwrap(), None, 40_000, 17);
    // B = ∅ (pure Schur inclusion).
    check_against_oracle(&kernel, Constraint::including(vec![1, 6]).unwrap(), None, 40_000, 19);
    // A = B = ∅ (factored fast path, unconditioned law).
    check_against_oracle(&kernel, Constraint::none(), None, 40_000, 23);
}

#[test]
fn m3_conditioned_sampling_matches_enumeration() {
    let kernel = kron3();
    let c = Constraint::new(vec![1], vec![6]).unwrap();
    check_against_oracle(&kernel, c, None, 40_000, 29);
    let c = Constraint::new(vec![1], vec![6]).unwrap();
    check_against_oracle(&kernel, c, Some(3), 40_000, 31);
}

#[test]
fn overlapping_constraints_are_rejected() {
    assert!(Constraint::new(vec![1, 3], vec![3]).is_err());
    // And out-of-bounds constraints fail at sampler construction.
    let kernel = kron2();
    let c = Constraint::including(vec![50]).unwrap();
    assert!(ConditionedSampler::new(&kernel, c).is_err());
}

#[test]
fn factored_marginals_match_dense_oracle_to_1e12() {
    // Acceptance criterion: all-N inclusion probabilities from the
    // factored O(N·(N₁+N₂)) path and per-entry factored queries agree
    // with the dense K = L(L+I)⁻¹ oracle to ≤ 1e-12 (m = 2 and m = 3).
    let mut scratch = MarginalScratch::new();
    let mut diag = Vec::new();
    for kernel in [kron2(), kron3()] {
        let eig = kernel.eigen().unwrap();
        let dense = kernel.marginal_kernel().unwrap();
        eig.inclusion_probabilities_into(&mut diag, &mut scratch);
        let n = kernel.n();
        assert_eq!(diag.len(), n);
        for i in 0..n {
            assert!(
                (diag[i] - dense[(i, i)]).abs() <= 1e-12,
                "diag {i}: {} vs {}",
                diag[i],
                dense[(i, i)]
            );
            for j in 0..n {
                let e = eig.marginal_entry(i, j);
                assert!(
                    (e - dense[(i, j)]).abs() <= 1e-12,
                    "K[{i},{j}]: {e} vs {}",
                    dense[(i, j)]
                );
            }
        }
    }
}

#[test]
fn conditioned_empirical_marginals_match_dense_conditional_kernel() {
    // Independent cross-check of the Schur identity: the conditional
    // law's per-item inclusion probabilities, computed densely from the
    // enumeration oracle, must match conditioned empirical frequencies.
    let kernel = kron2();
    let c = Constraint::new(vec![0], vec![8]).unwrap();
    let probs = oracle(&kernel, &c, None);
    let n = kernel.n();
    let mut incl = vec![0.0; n];
    for (y, p) in &probs {
        for &i in y {
            incl[i] += p;
        }
    }
    let cs = ConditionedSampler::new(&kernel, c).unwrap();
    let mut rng = Rng::new(37);
    let mut scratch = SampleScratch::new();
    let draws = 40_000;
    let mut counts = vec![0usize; n];
    let mut out = Vec::new();
    for _ in 0..draws {
        cs.sample_into(&mut rng, &mut scratch, &mut out);
        for &i in &out {
            counts[i] += 1;
        }
    }
    for i in 0..n {
        let emp = counts[i] as f64 / draws as f64;
        let se = (incl[i] * (1.0 - incl[i]) / draws as f64).sqrt();
        assert!(
            (emp - incl[i]).abs() < 6.0 * se + 0.01,
            "item {i}: empirical {emp:.4} vs conditional marginal {:.4}",
            incl[i]
        );
    }
    assert!((incl[0] - 1.0).abs() < 1e-12, "forced item has marginal 1");
    assert!(incl[8].abs() < 1e-12, "excluded item has marginal 0");
}
