//! Integration coverage for the incremental batched sampling engine:
//! determinism contracts of `sample_batch`, statistical agreement between
//! the batch path and the exact marginal kernel, scratch-reuse equivalence,
//! and the coordinator serving through the grouped engine.

use krondpp::config::ServiceConfig;
use krondpp::coordinator::DppService;
use krondpp::data;
use krondpp::dpp::{Kernel, SampleScratch, Sampler};
use krondpp::rng::Rng;

fn kernel(n1: usize, n2: usize, seed: u64) -> Kernel {
    let mut rng = Rng::new(seed);
    data::paper_truth_kernel(n1, n2, &mut rng)
}

#[test]
fn batch_is_deterministic_given_seed() {
    let s = Sampler::new(&kernel(4, 4, 1)).unwrap();
    for k in [None, Some(4usize)] {
        let a = s.sample_batch(50, k, 42);
        let b = s.sample_batch(50, k, 42);
        assert_eq!(a, b, "same seed must reproduce draws (k={k:?})");
    }
}

#[test]
fn batch_independent_of_thread_count() {
    let s = Sampler::new(&kernel(5, 4, 2)).unwrap();
    for k in [None, Some(3usize)] {
        let reference = s.sample_batch_threads(40, k, 7, 1);
        for threads in [2usize, 3, 8, 64] {
            assert_eq!(
                s.sample_batch_threads(40, k, 7, threads),
                reference,
                "threads={threads} changed draws (k={k:?})"
            );
        }
    }
}

#[test]
fn batch_marginals_agree_with_sequential_marginals() {
    // Batch draws and sequential scratch-reuse draws target the same
    // distribution: both empirical marginal vectors must sit within
    // sampling error of the exact K_ii.
    let kernel = kernel(3, 4, 3);
    let s = Sampler::new(&kernel).unwrap();
    let n = s.n();
    let draws = 4000;

    let batch = s.sample_batch(draws, None, 11);
    let mut batch_counts = vec![0usize; n];
    for y in &batch {
        for &i in y {
            batch_counts[i] += 1;
        }
    }

    let mut rng = Rng::new(12);
    let mut scratch = SampleScratch::new();
    let mut seq_counts = vec![0usize; n];
    for _ in 0..draws {
        for i in s.sample_with_scratch(&mut rng, &mut scratch) {
            seq_counts[i] += 1;
        }
    }

    // Kron kernel: exact K_ii via the factored diagonal (no dense K).
    let marg = s.eigen().inclusion_probabilities();
    for i in 0..n {
        let expect = marg[i];
        let se = (expect * (1.0 - expect) / draws as f64).sqrt();
        let tol = 5.0 * se + 0.01;
        let b = batch_counts[i] as f64 / draws as f64;
        let q = seq_counts[i] as f64 / draws as f64;
        assert!((b - expect).abs() < tol, "batch item {i}: {b} vs {expect}");
        assert!((q - expect).abs() < tol, "sequential item {i}: {q} vs {expect}");
    }
}

#[test]
fn scratch_reuse_is_invisible_in_results() {
    let s = Sampler::new(&kernel(4, 5, 4)).unwrap();
    let mut ra = Rng::new(31);
    let mut rb = Rng::new(31);
    let mut scratch = SampleScratch::new();
    for i in 0..40 {
        let with = s.sample_k_with_scratch(6, &mut ra, &mut scratch);
        let without = s.sample_k(6, &mut rb);
        assert_eq!(with, without, "draw {i}");
    }
}

#[test]
fn service_under_batched_engine_preserves_contract() {
    // End-to-end: the coordinator (grouped worker draws, per-worker
    // scratch) still honors per-request k and ground-set bounds.
    let cfg = ServiceConfig {
        workers: 3,
        max_batch: 8,
        batch_window_us: 300,
        queue_capacity: 10_000,
        ..ServiceConfig::default()
    };
    let svc = DppService::start(&kernel(4, 4, 5), &cfg, 17).unwrap();
    for round in 0..30 {
        let k = round % 6; // mixes k = 0 (unconstrained) with k-DPPs
        let y = svc.sample(k).unwrap();
        if k > 0 {
            assert_eq!(y.len(), k);
        }
        assert!(y.windows(2).all(|w| w[0] < w[1]));
        assert!(y.iter().all(|&i| i < 16));
    }
    svc.shutdown();
}
