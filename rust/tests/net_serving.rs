//! Loopback integration for the TCP serving layer: the wire boundary,
//! per-tenant admission control, SLO tracking, and graceful drain all
//! exercised over real sockets against a live [`DppService`].

use krondpp::config::{AdmissionPolicy, ServiceConfig};
use krondpp::coordinator::{DppService, NetConfig, NetServer, WireClient};
use krondpp::data;
use krondpp::dpp::{Kernel, KernelDelta, SampleMode};
use krondpp::error::ErrorKind;
use krondpp::rng::Rng;
use std::sync::atomic::Ordering;
use std::sync::Arc;
use std::time::Duration;

fn kernel(n1: usize, n2: usize, seed: u64) -> Kernel {
    let mut rng = Rng::new(seed);
    data::paper_truth_kernel(n1, n2, &mut rng)
}

fn boot(cfg: ServiceConfig) -> (Arc<DppService>, NetServer, String) {
    let svc = Arc::new(DppService::start(&kernel(4, 4, 1), &cfg, 2).unwrap());
    let server =
        NetServer::start(Arc::clone(&svc), "127.0.0.1:0", NetConfig::default()).unwrap();
    let addr = server.local_addr().to_string();
    (svc, server, addr)
}

fn quick_cfg() -> ServiceConfig {
    ServiceConfig {
        workers: 2,
        max_batch: 8,
        batch_window_us: 200,
        queue_capacity: 10_000,
        ..ServiceConfig::default()
    }
}

#[test]
fn end_to_end_ops_over_loopback() {
    let (svc, server, addr) = boot(quick_cfg());
    let mut client = WireClient::connect_timeout(&addr, Duration::from_secs(30)).unwrap();

    // Every backend of the zoo over the wire.
    for mode in [
        SampleMode::Exact,
        SampleMode::Mcmc { steps: 200 },
        SampleMode::LowRank { rank: 6 },
        SampleMode::Map,
    ] {
        let y = client.sample("default", 3, mode, vec![], vec![], None).unwrap();
        assert_eq!(y.len(), 3, "mode {mode:?}");
        assert!(y.iter().all(|&i| i < 16));
    }

    // Constraints ride along: pinned item in, excluded item out.
    let y = client
        .sample("default", 4, SampleMode::Exact, vec![2], vec![5, 7], None)
        .unwrap();
    assert!(y.contains(&2));
    assert!(!y.contains(&5) && !y.contains(&7));

    // Marginals match the in-process answer.
    let wire_m = client.marginals("default").unwrap();
    let tid = svc.tenant("default").unwrap();
    let local_m = svc.marginals(tid).unwrap();
    assert_eq!(wire_m.len(), local_m.len());
    for (a, b) in wire_m.iter().zip(local_m.iter()) {
        assert!((a - b).abs() < 1e-12);
    }

    // Delta publish over the wire bumps the generation.
    let gen0 = svc.registry().entry(tid).unwrap().generation();
    let id = client.next_id();
    let resp = client
        .request(&krondpp::ser::wire::WireRequest::PublishDelta {
            id,
            tenant: "default".into(),
            delta: KernelDelta::RetireItem { side: 0, index: 1, damping: 0.5 },
        })
        .unwrap();
    match resp {
        krondpp::ser::wire::WireResponse::Delta { generation, .. } => {
            assert!(generation > gen0);
        }
        other => panic!("expected delta outcome, got {other:?}"),
    }

    // Report renders the metrics text, including the throttle/SLO fields.
    let report = client.report().unwrap();
    assert!(report.contains("throttled="), "report: {report}");
    assert!(report.contains("slo_violations="), "report: {report}");

    // Graceful drain: shutdown acknowledged, loop exits, ledger closed.
    client.shutdown_server().unwrap();
    server.join();
    assert!(svc.is_shutdown());
    let m = svc.metrics();
    assert_eq!(
        m.accepted.load(Ordering::Relaxed),
        m.completed.load(Ordering::Relaxed),
        "every wire-accepted request completed"
    );
    assert_eq!(svc.in_flight(), 0);
}

#[test]
fn wire_errors_carry_kind_and_retryability() {
    let (_svc, server, addr) = boot(quick_cfg());
    let mut client = WireClient::connect_timeout(&addr, Duration::from_secs(30)).unwrap();

    // Unknown tenant -> Invalid, not retryable.
    let err = client
        .sample("nobody", 2, SampleMode::Exact, vec![], vec![], None)
        .unwrap_err();
    assert_eq!(err.kind(), ErrorKind::Invalid);
    assert!(!err.is_retryable());

    // k > N -> Invalid.
    let err = client
        .sample("default", 99, SampleMode::Exact, vec![], vec![], None)
        .unwrap_err();
    assert_eq!(err.kind(), ErrorKind::Invalid);

    // Overlapping include/exclude -> Invalid at constraint build.
    let err = client
        .sample("default", 3, SampleMode::Exact, vec![1], vec![1], None)
        .unwrap_err();
    assert_eq!(err.kind(), ErrorKind::Invalid);

    // The connection survived every payload error.
    let y = client.sample("default", 2, SampleMode::Exact, vec![], vec![], None).unwrap();
    assert_eq!(y.len(), 2);

    let mut ctl = WireClient::connect(&addr).unwrap();
    ctl.shutdown_server().unwrap();
    server.join();
}

/// Token-bucket throttling over the wire: the hog tenant sheds with
/// retryable `Throttled` errors at admission while the co-tenant keeps
/// completing, the ledger stays exact, and live-tuning the policy
/// reopens admission without a restart.
#[test]
fn rate_limited_tenant_sheds_while_cotenant_serves() {
    let (svc, server, addr) = boot(quick_cfg());
    let hog = svc.add_tenant("hog", &kernel(4, 4, 7)).unwrap();
    svc.add_tenant("quiet", &kernel(4, 4, 8)).unwrap();
    // 2 requests of headroom, then a trickle.
    svc.set_admission(
        hog,
        AdmissionPolicy { rate_hz: 1.0, burst: 2.0, ..AdmissionPolicy::default() },
    )
    .unwrap();

    let mut client = WireClient::connect_timeout(&addr, Duration::from_secs(30)).unwrap();
    let mut completed = 0usize;
    let mut throttled = 0usize;
    for _ in 0..10 {
        match client.sample("hog", 2, SampleMode::Exact, vec![], vec![], None) {
            Ok(_) => completed += 1,
            Err(e) => {
                assert_eq!(e.kind(), ErrorKind::Throttled, "unexpected error: {e}");
                assert!(e.is_retryable());
                throttled += 1;
            }
        }
    }
    assert!(completed >= 2, "burst must admit: {completed}");
    assert!(throttled > 0, "past-burst traffic must shed: {throttled}");

    // Co-tenant is untouched by the hog's limit.
    for _ in 0..5 {
        client.sample("quiet", 2, SampleMode::Exact, vec![], vec![], None).unwrap();
    }

    // Ledger: wire-observed tallies equal the per-tenant counters, and
    // throttles burned no queue slot (nothing was ever rejected).
    let entry = svc.registry().entry(hog).unwrap();
    let tm = entry.metrics();
    assert_eq!(tm.accepted.load(Ordering::Relaxed), completed as u64);
    assert_eq!(tm.throttled.load(Ordering::Relaxed), throttled as u64);
    assert_eq!(tm.completed.load(Ordering::Relaxed), completed as u64);
    assert_eq!(svc.metrics().rejected.load(Ordering::Relaxed), 0);
    assert_eq!(entry.outstanding(), 0);

    // Live tuning: lift the limit, the same tenant admits again.
    svc.set_admission(hog, AdmissionPolicy::default()).unwrap();
    for _ in 0..5 {
        client.sample("hog", 2, SampleMode::Exact, vec![], vec![], None).unwrap();
    }

    let mut ctl = WireClient::connect(&addr).unwrap();
    ctl.shutdown_server().unwrap();
    server.join();
}

/// Queue-wait/serve-time SLO accounting is reachable from the wire: a
/// tenant with a 0-tolerance SLO records a violation per completed
/// request, visible in the report.
#[test]
fn slo_violations_visible_over_wire() {
    let (svc, server, addr) = boot(quick_cfg());
    let t = svc.add_tenant("tight", &kernel(4, 4, 9)).unwrap();
    // slo_ms has millisecond floor; store the smallest nonzero SLO so
    // every real request (µs-ms scale) breaches it.
    svc.set_admission(t, AdmissionPolicy { slo_ms: 1, ..AdmissionPolicy::default() })
        .unwrap();
    let mut client = WireClient::connect_timeout(&addr, Duration::from_secs(30)).unwrap();
    // Saturate a slow mode so at least some requests exceed 1ms end to end.
    let mut done = 0;
    for _ in 0..20 {
        if client
            .sample("tight", 3, SampleMode::Mcmc { steps: 4000 }, vec![], vec![], None)
            .is_ok()
        {
            done += 1;
        }
    }
    assert!(done > 0);
    let entry = svc.registry().entry(t).unwrap();
    let violations = entry.metrics().slo_violations.load(Ordering::Relaxed);
    assert!(violations > 0, "1ms SLO with 4000-step MCMC must breach");
    let report = client.report().unwrap();
    assert!(report.contains("slo_violations="));

    let mut ctl = WireClient::connect(&addr).unwrap();
    ctl.shutdown_server().unwrap();
    server.join();
}

/// Drain with work in flight: requests pipelined right before the wire
/// shutdown still resolve (each gets a definitive response or a typed
/// error), the event loop exits, and new connections are refused.
#[test]
fn graceful_drain_settles_pipelined_work() {
    let cfg = ServiceConfig {
        workers: 1,
        max_batch: 64,
        // Long window: pipelined work is still queued when shutdown lands.
        batch_window_us: 100_000,
        queue_capacity: 10_000,
        ..ServiceConfig::default()
    };
    let (svc, server, addr) = boot(cfg);
    let mut client = WireClient::connect_timeout(&addr, Duration::from_secs(30)).unwrap();
    let mut ids = Vec::new();
    for _ in 0..16 {
        let id = client.next_id();
        client
            .send(&krondpp::ser::wire::WireRequest::Sample {
                id,
                tenant: "default".into(),
                k: 2,
                mode: SampleMode::Exact,
                include: vec![],
                exclude: vec![],
                budget_ms: None,
            })
            .unwrap();
        ids.push(id);
    }
    // Wait until the event loop has admitted all 16 (they sit in the
    // 100ms batch window), so the drain races the *queue*, not the read.
    let deadline = std::time::Instant::now() + Duration::from_secs(10);
    while svc.metrics().accepted.load(Ordering::Relaxed) < 16 {
        assert!(std::time::Instant::now() < deadline, "server never admitted the pipeline");
        std::thread::sleep(Duration::from_millis(1));
    }
    // Shutdown from a second connection while the 16 are in flight.
    let mut ctl = WireClient::connect_timeout(&addr, Duration::from_secs(30)).unwrap();
    ctl.shutdown_server().unwrap();

    // Every pipelined request settles with a definitive envelope.
    let mut settled = std::collections::BTreeSet::new();
    for _ in 0..ids.len() {
        let resp = client.recv().unwrap();
        let id = resp.id();
        // Outcome may be items or a typed shutdown-era error; both settle.
        let _ = resp.into_items();
        settled.insert(id);
    }
    assert_eq!(settled.len(), ids.len(), "every id answered exactly once");

    server.join();
    assert!(svc.is_shutdown());
    assert_eq!(svc.in_flight(), 0);
    // Ledger closed: accepted work completed, nothing dangles.
    let m = svc.metrics();
    assert_eq!(
        m.accepted.load(Ordering::Relaxed),
        m.completed.load(Ordering::Relaxed) + m.failed.load(Ordering::Relaxed),
    );
    // The drained listener refuses fresh connects (connect may succeed at
    // the TCP level and then close, or be refused outright).
    match WireClient::connect_timeout(&addr, Duration::from_secs(2)) {
        Ok(mut c) => {
            assert!(c.sample("default", 1, SampleMode::Exact, vec![], vec![], None).is_err());
        }
        Err(_) => {}
    }
}
