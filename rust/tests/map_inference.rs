//! Greedy MAP inference against brute-force oracles.
//!
//! The oracle enumerates every admissible subset (`N ≤ 12`, so at most
//! 4096 determinants) and takes the true `argmax det(L_Y)`. Greedy MAP
//! is exactly optimal on diagonal kernels and on any kernel where every
//! marginal gain exceeds one (auto-size mode then provably returns the
//! full admissible set); on random ensembles with `λ_min(L) ≥ 1` the
//! log-determinant objective is monotone submodular with `f(∅) = 0`, so
//! the classic Nemhauser–Wolsey–Fisher bound applies:
//! `logdet(greedy) ≥ (1 − 1/e) · logdet(opt)`.

mod common;

use common::stats::{seed, spd};
use krondpp::dpp::{
    map_slate, map_slate_auto, map_slate_constrained, map_slate_into, Constraint, Kernel,
    MapScratch,
};
use krondpp::linalg::{lu, Matrix};
use krondpp::rng::Rng;

/// Brute-force `argmax log det(L_Y)` over admissible subsets. `k = None`
/// ranges over every size (including the empty set at `log det = 0`).
fn oracle_best(
    dense: &Matrix,
    constraint: &Constraint,
    k: Option<usize>,
) -> (Vec<usize>, f64) {
    let n = dense.rows();
    assert!(n <= 12, "oracle is O(2^N)");
    let amask: u32 = constraint.include().iter().map(|&i| 1u32 << i).sum();
    let bmask: u32 = constraint.exclude().iter().map(|&i| 1u32 << i).sum();
    let mut best: Option<(Vec<usize>, f64)> = None;
    for mask in 0u32..(1u32 << n) {
        if mask & amask != amask || mask & bmask != 0 {
            continue;
        }
        if let Some(k) = k {
            if mask.count_ones() as usize != k {
                continue;
            }
        }
        let subset: Vec<usize> = (0..n).filter(|&i| mask >> i & 1 == 1).collect();
        let det = if subset.is_empty() {
            1.0
        } else {
            lu::det(&dense.principal_submatrix(&subset)).unwrap_or(0.0)
        };
        if det <= 0.0 {
            continue;
        }
        let ld = det.ln();
        let better = match &best {
            None => true,
            Some((_, b)) => ld > *b,
        };
        if better {
            best = Some((subset, ld));
        }
    }
    best.expect("no admissible subset with positive mass")
}

/// A random SPD ensemble member with `λ_min ≥ 1` (Wishart plus identity),
/// the regime where the (1 − 1/e) greedy guarantee is theorem-backed.
fn submodular_kernel(n: usize, seed: u64) -> Kernel {
    let mut rng = Rng::new(seed);
    let mut l = rng.wishart(n, n as f64 + 2.0, 1.0 / n as f64);
    l.add_diag_mut(1.0);
    Kernel::Full(l)
}

const QUALITY: f64 = 1.0 - 1.0 / std::f64::consts::E;

#[test]
fn greedy_is_exact_on_diagonal_kernels() {
    // On a diagonal kernel det(L_Y) = Π L_ii: the optimum for size k is
    // the top-k diagonal, and greedy picks exactly that.
    let diag = [0.7, 3.1, 1.4, 0.2, 2.6, 0.9, 5.0, 1.1];
    let n = diag.len();
    let mut l = Matrix::zeros(n, n);
    for (i, &d) in diag.iter().enumerate() {
        l.set(i, i, d);
    }
    let kernel = Kernel::Full(l.clone());
    for k in 1..=n {
        let slate = map_slate(&kernel, k).unwrap();
        let (opt, opt_ld) = oracle_best(&l, &Constraint::none(), Some(k));
        assert_eq!(slate, opt, "k = {k}: greedy diverged from the diagonal optimum");
        let ld: f64 = slate.iter().map(|&i| diag[i].ln()).sum();
        assert!((ld - opt_ld).abs() < 1e-12);
    }
    // Auto-size keeps exactly the diagonal entries above one.
    let auto = map_slate_auto(&kernel).unwrap();
    let want: Vec<usize> =
        (0..n).filter(|&i| diag[i] > 1.0).collect();
    assert_eq!(auto, want);
    let (opt, _) = oracle_best(&l, &Constraint::none(), None);
    assert_eq!(auto, opt, "auto-size diverged from the unconstrained optimum");
}

#[test]
fn greedy_meets_the_submodular_quality_bound_on_random_ensembles() {
    let mut scratch = MapScratch::new();
    let mut slate = Vec::new();
    for trial in 0..12u64 {
        let n = 6 + (trial as usize % 5); // 6..=10
        let kernel = submodular_kernel(n, seed() ^ (0x500 + trial));
        let dense = kernel.to_dense();
        for k in [2, 3, n / 2 + 1] {
            let ld = map_slate_into(
                &kernel,
                Some(k),
                &Constraint::none(),
                &mut scratch,
                &mut slate,
            )
            .unwrap();
            assert_eq!(slate.len(), k);
            let (_, opt_ld) = oracle_best(&dense, &Constraint::none(), Some(k));
            assert!(ld <= opt_ld + 1e-9, "greedy beat the oracle? {ld} > {opt_ld}");
            assert!(
                ld >= QUALITY * opt_ld - 1e-9,
                "trial {trial} N={n} k={k}: greedy {ld:.6} below \
                 (1-1/e)·opt = {:.6} (opt {opt_ld:.6})",
                QUALITY * opt_ld
            );
            // Sanity: the returned objective is the slate's true logdet.
            let direct = lu::det(&dense.principal_submatrix(&slate)).unwrap().ln();
            assert!((ld - direct).abs() < 1e-9);
        }
    }
}

#[test]
fn auto_size_is_optimal_when_every_gain_exceeds_one() {
    // λ_min(L) > 1 ⇒ every Schur-complement gain exceeds one (eigenvalue
    // interlacing), so adding any item always increases det(L_Y): the
    // optimum is the full set and auto-size greedy must find it.
    for trial in 0..6u64 {
        let n = 5 + (trial as usize % 4);
        let kernel = submodular_kernel(n, seed() ^ (0x600 + trial));
        let dense = kernel.to_dense();
        let slate = map_slate_auto(&kernel).unwrap();
        assert_eq!(slate, (0..n).collect::<Vec<_>>(), "trial {trial}");
        let (opt, _) = oracle_best(&dense, &Constraint::none(), None);
        assert_eq!(slate, opt);
    }
}

#[test]
fn quality_bound_holds_at_n_12() {
    // The acceptance-scale oracle case: every admissible subset of an
    // N = 12 kernel enumerated, greedy within the submodular bound.
    let kernel = submodular_kernel(12, seed() ^ 0x700);
    let dense = kernel.to_dense();
    for k in [3, 6, 9] {
        let slate = map_slate(&kernel, k).unwrap();
        let ld = lu::det(&dense.principal_submatrix(&slate)).unwrap().ln();
        let (_, opt_ld) = oracle_best(&dense, &Constraint::none(), Some(k));
        assert!(
            ld >= QUALITY * opt_ld - 1e-9,
            "N=12 k={k}: greedy {ld:.6} below bound ({opt_ld:.6} opt)"
        );
    }
}

#[test]
fn constrained_greedy_respects_constraints_across_random_cases() {
    // Property test: A always in, B never in, size exact, objective equal
    // to the slate's true logdet — across random Kronecker kernels,
    // constraint shapes and sizes.
    let mut scratch = MapScratch::new();
    let mut slate = Vec::new();
    let mut rng = Rng::new(seed() ^ 0x800);
    for trial in 0..20u64 {
        let kernel = Kernel::Kron2(spd(3, 900 + trial), spd(3, 950 + trial));
        let n = kernel.n();
        // Random disjoint include/exclude pair.
        let mut items: Vec<usize> = (0..n).collect();
        for i in 0..4 {
            let j = i + rng.below(n - i);
            items.swap(i, j);
        }
        let include = vec![items[0]];
        let exclude = vec![items[1], items[2]];
        let c = Constraint::new(include.clone(), exclude.clone()).unwrap();
        let k = 2 + rng.below(4); // 2..=5, ≥ |A|, ≤ n − |B|
        let ld = map_slate_into(&kernel, Some(k), &c, &mut scratch, &mut slate).unwrap();
        assert_eq!(slate.len(), k, "trial {trial}");
        assert!(slate.contains(&include[0]), "trial {trial}: include dropped");
        assert!(
            exclude.iter().all(|b| !slate.contains(b)),
            "trial {trial}: exclude violated"
        );
        assert!(slate.windows(2).all(|w| w[0] < w[1]));
        let direct =
            lu::det(&kernel.to_dense().principal_submatrix(&slate)).unwrap().ln();
        assert!((ld - direct).abs() < 1e-9, "trial {trial}: objective mismatch");
    }
}

#[test]
fn constrained_greedy_is_exact_on_diagonal_kernels() {
    let diag = [0.4, 2.0, 1.5, 3.0, 0.8, 2.5];
    let n = diag.len();
    let mut l = Matrix::zeros(n, n);
    for (i, &d) in diag.iter().enumerate() {
        l.set(i, i, d);
    }
    let kernel = Kernel::Full(l.clone());
    // Force in a weak item, ban the strongest: greedy must still pick the
    // best admissible remainder — exactly the constrained oracle.
    let c = Constraint::new(vec![0], vec![3]).unwrap();
    for k in 2..=4 {
        let slate = map_slate_constrained(&kernel, Some(k), &c).unwrap();
        let (opt, _) = oracle_best(&l, &c, Some(k));
        assert_eq!(slate, opt, "k = {k}");
    }
    let auto = map_slate_constrained(&kernel, None, &c).unwrap();
    let (opt, _) = oracle_best(&l, &c, None);
    assert_eq!(auto, opt, "auto-size constrained");
}
