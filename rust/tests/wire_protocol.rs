//! Malformed-frame suite against a live server over raw sockets: every
//! hostile byte sequence must map to either a clean error envelope (the
//! connection survives) or a clean connection close (the server
//! survives) — never a panic, and never a leaked queue slot.

use krondpp::config::ServiceConfig;
use krondpp::coordinator::{DppService, NetConfig, NetServer, WireClient};
use krondpp::data;
use krondpp::dpp::SampleMode;
use krondpp::rng::Rng;
use krondpp::ser::wire::{encode_frame, FrameReader, WireResponse, DEFAULT_MAX_FRAME};
use std::io::{Read, Write};
use std::net::TcpStream;
use std::sync::atomic::Ordering;
use std::sync::Arc;
use std::time::Duration;

fn boot() -> (Arc<DppService>, NetServer, String) {
    let mut rng = Rng::new(11);
    let kernel = data::paper_truth_kernel(4, 4, &mut rng);
    let cfg = ServiceConfig {
        workers: 2,
        max_batch: 8,
        batch_window_us: 200,
        ..ServiceConfig::default()
    };
    let svc = Arc::new(DppService::start(&kernel, &cfg, 2).unwrap());
    let server =
        NetServer::start(Arc::clone(&svc), "127.0.0.1:0", NetConfig::default()).unwrap();
    let addr = server.local_addr().to_string();
    (svc, server, addr)
}

fn raw_connect(addr: &str) -> TcpStream {
    let stream = TcpStream::connect(addr).unwrap();
    stream.set_read_timeout(Some(Duration::from_secs(30))).unwrap();
    stream.set_nodelay(true).unwrap();
    stream
}

/// Blocking-read the next complete response frame off a raw stream.
fn read_response(stream: &mut TcpStream, reader: &mut FrameReader) -> WireResponse {
    loop {
        if let Some(payload) = reader.next().unwrap() {
            return WireResponse::from_payload(&payload).unwrap();
        }
        let mut chunk = [0u8; 4096];
        let n = stream.read(&mut chunk).unwrap();
        assert!(n > 0, "server closed while a response was expected");
        reader.push(&chunk[..n]);
    }
}

/// Read until EOF, tolerating any best-effort frames the server flushes
/// on its way out. Panics (via the read timeout) if the server never
/// closes.
fn read_until_eof(stream: &mut TcpStream) -> Vec<u8> {
    let mut all = Vec::new();
    let mut chunk = [0u8; 4096];
    loop {
        match stream.read(&mut chunk) {
            Ok(0) => return all,
            Ok(n) => all.extend_from_slice(&chunk[..n]),
            Err(e) => panic!("expected clean close, got {e}"),
        }
    }
}

fn expect_failure(resp: &WireResponse, expect_kind: &str) {
    match resp {
        WireResponse::Failure { kind, retryable, .. } => {
            assert_eq!(kind, expect_kind);
            assert!(!retryable, "malformed input must not be retryable");
        }
        other => panic!("expected {expect_kind} failure envelope, got {other:?}"),
    }
}

fn shutdown(addr: &str, server: NetServer, svc: &DppService) {
    let mut ctl = WireClient::connect_timeout(addr, Duration::from_secs(30)).unwrap();
    ctl.shutdown_server().unwrap();
    server.join();
    // No slot leak anywhere in the suite: the ledger is closed.
    assert_eq!(svc.in_flight(), 0);
    let m = svc.metrics();
    assert_eq!(
        m.accepted.load(Ordering::Relaxed),
        m.completed.load(Ordering::Relaxed) + m.failed.load(Ordering::Relaxed),
    );
}

/// Garbage JSON and non-UTF8 payloads are *payload* errors: the server
/// answers an error envelope and the connection keeps serving.
#[test]
fn payload_errors_keep_the_connection_open() {
    let (svc, server, addr) = boot();
    let mut stream = raw_connect(&addr);
    let mut reader = FrameReader::new(DEFAULT_MAX_FRAME);

    // Garbage JSON in a well-formed frame.
    let frame = encode_frame(b"{this is not json", DEFAULT_MAX_FRAME).unwrap();
    stream.write_all(&frame).unwrap();
    expect_failure(&read_response(&mut stream, &mut reader), "parse");

    // Non-UTF8 bytes in a well-formed frame.
    let frame = encode_frame(&[0xff, 0xfe, 0x80, 0x01], DEFAULT_MAX_FRAME).unwrap();
    stream.write_all(&frame).unwrap();
    expect_failure(&read_response(&mut stream, &mut reader), "parse");

    // Valid JSON, unknown op.
    let frame = encode_frame(b"{\"id\": 9, \"op\": \"steal\"}", DEFAULT_MAX_FRAME).unwrap();
    stream.write_all(&frame).unwrap();
    expect_failure(&read_response(&mut stream, &mut reader), "parse");

    // The same socket still serves a real request afterward.
    let frame = encode_frame(
        b"{\"id\": 10, \"op\": \"sample\", \"tenant\": \"default\", \"k\": 2}",
        DEFAULT_MAX_FRAME,
    )
    .unwrap();
    stream.write_all(&frame).unwrap();
    match read_response(&mut stream, &mut reader) {
        WireResponse::Items { id, items } => {
            assert_eq!(id, 10);
            assert_eq!(items.len(), 2);
        }
        other => panic!("expected items after payload errors, got {other:?}"),
    }

    assert!(server.stats().payload_errors.load(Ordering::Relaxed) >= 3);
    drop(stream);
    shutdown(&addr, server, &svc);
}

/// An oversized declared length is a *frame* error: the connection is
/// closed (best-effort error envelope first), but the server and every
/// other connection keep going.
#[test]
fn oversized_frame_closes_only_that_connection() {
    let (svc, server, addr) = boot();
    let mut stream = raw_connect(&addr);

    // Declare a payload twice the cap; never send it.
    let declared = (2 * DEFAULT_MAX_FRAME) as u32;
    stream.write_all(&declared.to_be_bytes()).unwrap();
    let leftovers = read_until_eof(&mut stream);

    // Whatever was flushed before the close must itself be well-framed.
    let mut reader = FrameReader::new(DEFAULT_MAX_FRAME);
    reader.push(&leftovers);
    if let Some(payload) = reader.next().unwrap() {
        expect_failure(&WireResponse::from_payload(&payload).unwrap(), "parse");
    }

    // A fresh connection is unaffected.
    let mut client = WireClient::connect_timeout(&addr, Duration::from_secs(30)).unwrap();
    let y = client.sample("default", 3, SampleMode::Exact, vec![], vec![], None).unwrap();
    assert_eq!(y.len(), 3);
    assert!(server.stats().protocol_errors.load(Ordering::Relaxed) >= 1);

    drop(client);
    shutdown(&addr, server, &svc);
}

/// Truncated prefixes and half-delivered frames followed by an abrupt
/// client disconnect must not panic the loop or leak state.
#[test]
fn truncated_frames_and_abrupt_disconnects_are_harmless() {
    let (svc, server, addr) = boot();

    // Two bytes of a length prefix, then close.
    let mut stream = raw_connect(&addr);
    stream.write_all(&[0x00, 0x00]).unwrap();
    drop(stream);

    // A full prefix declaring 100 bytes, 10 bytes delivered, then close.
    let mut stream = raw_connect(&addr);
    stream.write_all(&100u32.to_be_bytes()).unwrap();
    stream.write_all(&[0x7b; 10]).unwrap();
    drop(stream);

    // A valid request frame truncated mid-payload, then close.
    let frame = encode_frame(
        b"{\"id\": 1, \"op\": \"sample\", \"tenant\": \"default\", \"k\": 2}",
        DEFAULT_MAX_FRAME,
    )
    .unwrap();
    let mut stream = raw_connect(&addr);
    stream.write_all(&frame[..frame.len() / 2]).unwrap();
    drop(stream);

    // The loop absorbed all three without dying: wait for the closes to
    // be booked, then serve a real request.
    let deadline = std::time::Instant::now() + Duration::from_secs(10);
    while server.stats().closed.load(Ordering::Relaxed) < 3 {
        assert!(std::time::Instant::now() < deadline, "disconnects never booked");
        std::thread::sleep(Duration::from_millis(1));
    }
    let mut client = WireClient::connect_timeout(&addr, Duration::from_secs(30)).unwrap();
    let y = client.sample("default", 2, SampleMode::Exact, vec![], vec![], None).unwrap();
    assert_eq!(y.len(), 2);

    drop(client);
    shutdown(&addr, server, &svc);
}

/// A client that pipelines a request and vanishes before reading the
/// answer: the worker still books an outcome, the connection is reaped,
/// and the ledger closes exactly.
#[test]
fn disconnect_with_request_in_flight_leaks_nothing() {
    let (svc, server, addr) = boot();

    let frame = encode_frame(
        b"{\"id\": 1, \"op\": \"sample\", \"tenant\": \"default\", \"k\": 3}",
        DEFAULT_MAX_FRAME,
    )
    .unwrap();
    let mut stream = raw_connect(&addr);
    stream.write_all(&frame).unwrap();
    // Half-close the write side so the server sees EOF with the request
    // already admitted, then drop without reading the response.
    stream.shutdown(std::net::Shutdown::Write).unwrap();
    drop(stream);

    // The accepted job must settle in the service ledger even though the
    // reply had nowhere to go.
    let deadline = std::time::Instant::now() + Duration::from_secs(10);
    loop {
        let m = svc.metrics();
        let acc = m.accepted.load(Ordering::Relaxed);
        let settled =
            m.completed.load(Ordering::Relaxed) + m.failed.load(Ordering::Relaxed);
        if acc >= 1 && settled == acc && svc.in_flight() == 0 {
            break;
        }
        assert!(std::time::Instant::now() < deadline, "orphaned job never settled");
        std::thread::sleep(Duration::from_millis(1));
    }

    shutdown(&addr, server, &svc);
}
