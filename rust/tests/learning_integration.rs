//! Cross-module integration: learners × data generators × likelihood ×
//! samplers, exercised end-to-end at small scale. These are the "does the
//! whole library compose" tests, one step up from the per-module units.

use krondpp::data;
use krondpp::dpp::likelihood::log_likelihood;
use krondpp::dpp::{Kernel, Sampler};
use krondpp::learn::{init, JointPicard, KrkPicard, KrkStochastic, Learner, Picard};
use krondpp::rng::Rng;
use krondpp::testing::{check, SubsetGen};

fn setup(n1: usize, n2: usize, count: usize, seed: u64) -> (Kernel, krondpp::learn::TrainingSet) {
    let mut rng = Rng::new(seed);
    let truth = data::paper_truth_kernel(n1, n2, &mut rng);
    let train = data::sample_training_set(
        &truth,
        count,
        (n1 * n2 / 10).max(2),
        (n1 * n2 / 3).max(4),
        &mut rng,
    )
    .unwrap();
    (truth, train)
}

#[test]
fn all_learners_improve_same_problem() {
    let (truth, train) = setup(4, 4, 50, 1);
    let n = truth.n();
    let mut rng = Rng::new(2);
    let l1 = init::paper_subkernel(4, &mut rng);
    let l2 = init::paper_subkernel(4, &mut rng);
    let l0 = krondpp::linalg::kron::kron(&l1, &l2);
    let truth_ll = log_likelihood(&truth, &train.subsets).unwrap();

    let learners: Vec<(Box<dyn Learner>, usize)> = vec![
        (Box::new(Picard::new(l0.clone(), 1.0).unwrap()), 15),
        (Box::new(KrkPicard::new(l1.clone(), l2.clone(), 1.0).unwrap()), 15),
        (Box::new(KrkStochastic::new(l1.clone(), l2.clone(), 0.6, 4, 3)), 30),
        (Box::new(JointPicard::new(l1.clone(), l2.clone(), 1.0).unwrap()), 15),
    ];
    for (mut learner, iters) in learners {
        let name = learner.name();
        let r = learner.run(&train, iters, 0.0).unwrap();
        let gain = r.final_ll() - r.history[0].log_likelihood;
        assert!(gain > 0.0, "{name} did not improve ({gain})");
        // Learned kernel should approach the truth's likelihood.
        assert!(
            r.final_ll() > truth_ll - 12.0,
            "{name} final ll {} far below truth {truth_ll}",
            r.final_ll()
        );
        // And it must be a valid sampling kernel.
        let mut srng = Rng::new(9);
        let y = Sampler::new(&r.kernel).unwrap().sample_k(3, &mut srng);
        assert_eq!(y.len(), 3);
        assert!(y.iter().all(|&i| i < n));
    }
}

#[test]
fn krk_learns_structure_better_than_size_matched_baseline() {
    // On truly Kronecker-structured data, KRK with the right factorization
    // should at least match a full Picard given the *same* iteration count
    // on likelihood-per-second (it does strictly more iterations per unit
    // time; here we check likelihood parity at equal iterations).
    let (_, train) = setup(4, 5, 60, 4);
    let mut rng = Rng::new(5);
    let l1 = init::paper_subkernel(4, &mut rng);
    let l2 = init::paper_subkernel(5, &mut rng);
    let mut krk = KrkPicard::new(l1.clone(), l2.clone(), 1.0).unwrap();
    let kr = krk.run(&train, 20, 0.0).unwrap();
    let mut pic = Picard::new(krondpp::linalg::kron::kron(&l1, &l2), 1.0).unwrap();
    let pr = pic.run(&train, 20, 0.0).unwrap();
    assert!(
        kr.final_ll() > pr.final_ll() - 1.0,
        "krk {} lost badly to picard {} on Kron-structured data",
        kr.final_ll(),
        pr.final_ll()
    );
}

#[test]
fn stochastic_epochs_converge_toward_batch_fixed_point() {
    let (_, train) = setup(3, 3, 40, 7);
    let mut rng = Rng::new(8);
    let l1 = init::paper_subkernel(3, &mut rng);
    let l2 = init::paper_subkernel(3, &mut rng);
    let mut batch = KrkPicard::new(l1.clone(), l2.clone(), 1.0).unwrap();
    let br = batch.run(&train, 30, 0.0).unwrap();
    let mut stoch = KrkStochastic::new(l1, l2, 0.5, 8, 9);
    let sr = stoch.run(&train, 60, 0.0).unwrap();
    assert!(
        (sr.final_ll() - br.final_ll()).abs() < 1.5,
        "stochastic {} vs batch {} fixed points diverged",
        sr.final_ll(),
        br.final_ll()
    );
}

#[test]
fn prop_likelihood_consistent_between_structured_and_dense() {
    // For random subsets, φ computed on Kron2(L1,L2) == φ on the dense
    // product — across many random subsets (property test).
    let (truth, _) = setup(3, 4, 1, 10);
    let dense = Kernel::Full(truth.to_dense());
    let gen = SubsetGen { n: 12, klo: 1, khi: 6 };
    check("likelihood structured==dense", &gen, 40, |y| {
        let a = log_likelihood(&truth, std::slice::from_ref(y)).unwrap();
        let b = log_likelihood(&dense, std::slice::from_ref(y)).unwrap();
        (a - b).abs() < 1e-8
    });
}

#[test]
fn dataset_roundtrip_preserves_learning() {
    // Save → load → learn gives identical history to in-memory data.
    let (_, train) = setup(3, 3, 25, 11);
    let dir = std::env::temp_dir().join(format!("krondpp-it-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("train.kds");
    krondpp::ser::matio::write_dataset(&path, train.ground_size, &train.subsets).unwrap();
    let (n, subsets) = krondpp::ser::matio::read_dataset(&path).unwrap();
    let reloaded = krondpp::learn::TrainingSet::new(n, subsets).unwrap();

    let mut rng = Rng::new(12);
    let l1 = init::paper_subkernel(3, &mut rng);
    let l2 = init::paper_subkernel(3, &mut rng);
    let mut a = KrkPicard::new(l1.clone(), l2.clone(), 1.0).unwrap();
    let ra = a.run(&train, 5, 0.0).unwrap();
    let mut b = KrkPicard::new(l1, l2, 1.0).unwrap();
    let rb = b.run(&reloaded, 5, 0.0).unwrap();
    for (x, y) in ra.history.iter().zip(&rb.history) {
        assert!((x.log_likelihood - y.log_likelihood).abs() < 1e-12);
    }
}

#[test]
fn kron3_sampling_and_likelihood_compose() {
    let mut rng = Rng::new(13);
    let a = init::paper_subkernel(3, &mut rng);
    let b = init::paper_subkernel(3, &mut rng);
    let c = init::paper_subkernel(2, &mut rng);
    let k3 = Kernel::Kron3(a, b, c);
    let sampler = Sampler::new(&k3).unwrap();
    let subsets: Vec<Vec<usize>> = (0..20).map(|_| sampler.sample(&mut rng)).collect();
    let ll = log_likelihood(&k3, &subsets).unwrap();
    let dense_ll = log_likelihood(&Kernel::Full(k3.to_dense()), &subsets).unwrap();
    assert!((ll - dense_ll).abs() < 1e-8);
}
