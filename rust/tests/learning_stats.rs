//! Engine-vs-oracle property suite for the Θ-free compressed training
//! statistics (`learn::stats`): the engine's `O(nκ²)` accumulations must
//! reproduce the dense scatter-then-contract oracle
//! (`theta_dense` + `kron::{block_trace, weighted_block_sum,
//! mixed_weighted_trace}`) to ≤ 1e-12 relative difference on random
//! Kronecker kernels — including duplicate, singleton and empty subsets —
//! and be bitwise invariant to the worker-thread count.

use krondpp::dpp::likelihood::{log_likelihood, subset_logdet, theta_dense};
use krondpp::dpp::Kernel;
use krondpp::learn::krk::{Contractions, KrkPicard};
use krondpp::learn::stats::{
    CompressedTraining, Contraction, KernelRef, KernelShape, ThetaEngine,
};
use krondpp::learn::traits::{Learner, TrainingSet};
use krondpp::linalg::{kron, Matrix};
use krondpp::rng::Rng;

fn spd(n: usize, rng: &mut Rng) -> Matrix {
    let mut m = rng.paper_init_kernel(n);
    m.scale_mut(1.5 / n as f64);
    m.add_diag_mut(0.3);
    m
}

/// Random subsets over `[0, n)` with duplicates, singletons and empties.
fn messy_subsets(n: usize, count: usize, kmax: usize, rng: &mut Rng) -> Vec<Vec<usize>> {
    let mut out: Vec<Vec<usize>> = Vec::new();
    for i in 0..count {
        if i % 7 == 3 {
            out.push(Vec::new()); // empty
        } else if i % 5 == 2 && !out.is_empty() {
            let prev = out[rng.int_range(0, out.len() - 1)].clone();
            out.push(prev); // duplicate of an earlier subset
        } else if i % 4 == 1 {
            out.push(vec![rng.int_range(0, n - 1)]); // singleton
        } else {
            let k = rng.int_range(1, kmax);
            out.push(rng.subset(n, k));
        }
    }
    out
}

#[test]
fn m2_contractions_match_dense_oracle() {
    let mut rng = Rng::new(101);
    let (n1, n2) = (3usize, 4usize);
    let (l1, l2) = (spd(n1, &mut rng), spd(n2, &mut rng));
    let kernel = Kernel::Kron2(l1.clone(), l2.clone());
    let subsets = messy_subsets(n1 * n2, 30, 5, &mut rng);
    let theta = theta_dense(&kernel, &subsets).unwrap();
    let a1_oracle = kron::block_trace(&theta, &l2, n1, n2).unwrap();
    let a2_oracle = kron::weighted_block_sum(&theta, &l1, n1, n2).unwrap();

    let stats =
        CompressedTraining::new(&subsets, KernelShape::Kron2 { n1, n2 }).unwrap();
    assert!(stats.unique() < subsets.len(), "test data must contain duplicates");
    let mut eng = ThetaEngine::new();
    let mut a1 = Matrix::zeros(0, 0);
    let ld1 = eng
        .contract(KernelRef::Kron2(&l1, &l2), &stats, Contraction::A1, &mut a1)
        .unwrap();
    let mut a2 = Matrix::zeros(0, 0);
    let ld2 = eng
        .contract(KernelRef::Kron2(&l1, &l2), &stats, Contraction::A2, &mut a2)
        .unwrap();
    assert!(a1.rel_diff(&a1_oracle) <= 1e-12, "A1: {}", a1.rel_diff(&a1_oracle));
    assert!(a2.rel_diff(&a2_oracle) <= 1e-12, "A2: {}", a2.rel_diff(&a2_oracle));

    // Fused data term = (1/n)·Σᵢ log det L_{Yᵢ} (empties contribute 0).
    let want: f64 = subsets
        .iter()
        .map(|y| subset_logdet(&kernel, y).unwrap())
        .sum::<f64>()
        / subsets.len() as f64;
    assert!((ld1 - want).abs() < 1e-12, "{ld1} vs {want}");
    assert!((ld2 - want).abs() < 1e-12);
    let only_ld = eng.sum_logdet(KernelRef::Kron2(&l1, &l2), &stats).unwrap();
    assert!((only_ld - want).abs() < 1e-12);
}

#[test]
fn m3_contractions_match_dense_oracle() {
    let mut rng = Rng::new(202);
    let (n1, n2, n3) = (2usize, 3usize, 2usize);
    let (l1, l2, l3) = (spd(n1, &mut rng), spd(n2, &mut rng), spd(n3, &mut rng));
    let kernel = Kernel::Kron3(l1.clone(), l2.clone(), l3.clone());
    let subsets = messy_subsets(n1 * n2 * n3, 24, 4, &mut rng);
    let theta = theta_dense(&kernel, &subsets).unwrap();
    // Oracles: grouped factors materialized only here, in the test.
    let b = kron::kron(&l2, &l3);
    let a = kron::kron(&l1, &l2);
    let a1_oracle = kron::block_trace(&theta, &b, n1, n2 * n3).unwrap();
    let h_oracle =
        kron::mixed_weighted_trace(&theta, &l1, &l3, n1, n2, n3).unwrap();
    let a2_oracle = kron::weighted_block_sum(&theta, &a, n1 * n2, n3).unwrap();

    let stats =
        CompressedTraining::new(&subsets, KernelShape::Kron3 { n1, n2, n3 }).unwrap();
    let mut eng = ThetaEngine::new();
    let kref = KernelRef::Kron3(&l1, &l2, &l3);
    let mut out = Matrix::zeros(0, 0);
    eng.contract(kref, &stats, Contraction::A1, &mut out).unwrap();
    assert!(out.rel_diff(&a1_oracle) <= 1e-12, "A1g: {}", out.rel_diff(&a1_oracle));
    eng.contract(kref, &stats, Contraction::Mid, &mut out).unwrap();
    assert!(out.rel_diff(&h_oracle) <= 1e-12, "H: {}", out.rel_diff(&h_oracle));
    eng.contract(kref, &stats, Contraction::A2, &mut out).unwrap();
    assert!(out.rel_diff(&a2_oracle) <= 1e-12, "A2g: {}", out.rel_diff(&a2_oracle));
}

#[test]
fn results_are_bitwise_invariant_across_thread_caps() {
    let mut rng = Rng::new(303);
    let (n1, n2) = (4usize, 5usize);
    let (l1, l2) = (spd(n1, &mut rng), spd(n2, &mut rng));
    // Enough unique subsets to cross the parallel-dispatch threshold.
    let subsets = messy_subsets(n1 * n2, 160, 6, &mut rng);
    let stats =
        CompressedTraining::new(&subsets, KernelShape::Kron2 { n1, n2 }).unwrap();
    assert!(stats.unique() >= 48, "need enough uniques to spawn workers");
    let kref = KernelRef::Kron2(&l1, &l2);
    let mut reference: Option<(Vec<f64>, Vec<f64>, f64)> = None;
    for cap in [1usize, 2, 5, 16] {
        let mut eng = ThetaEngine::new();
        eng.set_thread_cap(cap);
        let mut a1 = Matrix::zeros(0, 0);
        let ld = eng.contract(kref, &stats, Contraction::A1, &mut a1).unwrap();
        let mut a2 = Matrix::zeros(0, 0);
        eng.contract(kref, &stats, Contraction::A2, &mut a2).unwrap();
        match &reference {
            None => reference = Some((a1.as_slice().to_vec(), a2.as_slice().to_vec(), ld)),
            Some((r1, r2, rld)) => {
                assert_eq!(a1.as_slice(), &r1[..], "A1 not bitwise equal at cap={cap}");
                assert_eq!(a2.as_slice(), &r2[..], "A2 not bitwise equal at cap={cap}");
                assert!(ld.to_bits() == rld.to_bits(), "logdet differs at cap={cap}");
            }
        }
    }
    // The dense-Θ path (phase-1 pool + row-panel scatter) too.
    let mut reference: Option<(Vec<f64>, f64)> = None;
    for cap in [1usize, 3, 16] {
        let mut eng = ThetaEngine::new();
        eng.set_thread_cap(cap);
        let mut theta = Matrix::zeros(0, 0);
        let ld = eng.theta_dense_into(kref, &stats, &mut theta).unwrap();
        match &reference {
            None => reference = Some((theta.as_slice().to_vec(), ld)),
            Some((r, rld)) => {
                assert_eq!(theta.as_slice(), &r[..], "Θ not bitwise equal at cap={cap}");
                assert!(ld.to_bits() == rld.to_bits());
            }
        }
    }
}

#[test]
fn theta_dense_into_matches_oracle() {
    let mut rng = Rng::new(404);
    let (n1, n2) = (3usize, 4usize);
    let (l1, l2) = (spd(n1, &mut rng), spd(n2, &mut rng));
    let kernel = Kernel::Kron2(l1.clone(), l2.clone());
    let subsets = messy_subsets(n1 * n2, 25, 5, &mut rng);
    let oracle = theta_dense(&kernel, &subsets).unwrap();
    let stats =
        CompressedTraining::new(&subsets, KernelShape::Kron2 { n1, n2 }).unwrap();
    let mut eng = ThetaEngine::new();
    let mut theta = Matrix::zeros(0, 0);
    eng.theta_dense_into(KernelRef::Kron2(&l1, &l2), &stats, &mut theta).unwrap();
    assert!(theta.rel_diff(&oracle) <= 1e-12, "{}", theta.rel_diff(&oracle));
    // Full (unstructured) gather path.
    let lf = kernel.to_dense();
    let fstats =
        CompressedTraining::new(&subsets, KernelShape::Full { n: n1 * n2 }).unwrap();
    eng.theta_dense_into(KernelRef::Full(&lf), &fstats, &mut theta).unwrap();
    assert!(theta.rel_diff(&oracle) <= 1e-12);
}

#[test]
fn krk_engine_step_matches_dense_backend_step() {
    /// Θ-consuming backend exercising the trait's dense default for
    /// `contract_compressed` — the pre-engine semantics.
    struct DenseOracle;
    impl Contractions for DenseOracle {
        fn block_trace(
            &self,
            theta: &Matrix,
            l2: &Matrix,
            n1: usize,
            n2: usize,
        ) -> krondpp::error::Result<Matrix> {
            kron::block_trace(theta, l2, n1, n2)
        }
        fn weighted_block_sum(
            &self,
            theta: &Matrix,
            w: &Matrix,
            n1: usize,
            n2: usize,
        ) -> krondpp::error::Result<Matrix> {
            kron::weighted_block_sum(theta, w, n1, n2)
        }
    }

    let mut rng = Rng::new(505);
    let (n1, n2) = (3usize, 4usize);
    let (l1, l2) = (spd(n1, &mut rng), spd(n2, &mut rng));
    let subsets = messy_subsets(n1 * n2, 30, 5, &mut rng);
    let data = TrainingSet::new(n1 * n2, subsets).unwrap();
    let mut engine_learner = KrkPicard::new(l1.clone(), l2.clone(), 1.0).unwrap();
    let mut dense_learner =
        KrkPicard::with_backend(l1, l2, 1.0, Box::new(DenseOracle)).unwrap();
    for it in 0..3 {
        engine_learner.step(&data).unwrap();
        dense_learner.step(&data).unwrap();
        let (e1, e2) = engine_learner.subkernels();
        let (d1, d2) = dense_learner.subkernels();
        // Per-contraction agreement is ≤ 1e-12 (asserted above); across
        // three full steps the tiny association differences compound
        // through sandwiches and eigensolves, so the iterate tolerance is
        // a notch looser.
        assert!(e1.rel_diff(d1) <= 1e-10, "iter {it} L1: {}", e1.rel_diff(d1));
        assert!(e2.rel_diff(d2) <= 1e-10, "iter {it} L2: {}", e2.rel_diff(d2));
    }
}

#[test]
fn fused_pre_step_objective_and_objective_match_dense_likelihood() {
    let mut rng = Rng::new(606);
    let (n1, n2) = (3usize, 3usize);
    let (l1, l2) = (spd(n1, &mut rng), spd(n2, &mut rng));
    let subsets = messy_subsets(n1 * n2, 26, 4, &mut rng);
    let data = TrainingSet::new(n1 * n2, subsets).unwrap();
    let mut learner = KrkPicard::new(l1, l2, 1.0).unwrap();
    assert!(learner.pre_step_objective().is_none());
    // objective() (engine path) vs the dense Eq.-3 evaluation.
    let dense = log_likelihood(&learner.kernel(), &data.subsets).unwrap();
    let fused = learner.objective(&data).unwrap();
    assert!((fused - dense).abs() < 1e-9, "{fused} vs {dense}");
    // pre_step_objective = φ at the iterate entering the step.
    let before = log_likelihood(&learner.kernel(), &data.subsets).unwrap();
    learner.step(&data).unwrap();
    let fused_pre = learner.pre_step_objective().unwrap();
    assert!((fused_pre - before).abs() < 1e-9, "{fused_pre} vs {before}");
}

#[test]
fn contract_batch_over_everything_matches_compressed_sweep() {
    let mut rng = Rng::new(707);
    let (n1, n2) = (3usize, 4usize);
    let (l1, l2) = (spd(n1, &mut rng), spd(n2, &mut rng));
    let subsets = messy_subsets(n1 * n2, 20, 5, &mut rng);
    let stats =
        CompressedTraining::new(&subsets, KernelShape::Kron2 { n1, n2 }).unwrap();
    let kref = KernelRef::Kron2(&l1, &l2);
    let mut eng = ThetaEngine::new();
    let mut full = Matrix::zeros(0, 0);
    eng.contract(kref, &stats, Contraction::A1, &mut full).unwrap();
    let batch: Vec<usize> = (0..subsets.len()).collect();
    let mut batched = Matrix::zeros(0, 0);
    eng.contract_batch(
        kref,
        &subsets,
        &batch,
        1.0 / subsets.len() as f64,
        Contraction::A1,
        &mut batched,
    )
    .unwrap();
    assert!(batched.rel_diff(&full) <= 1e-12, "{}", batched.rel_diff(&full));
}
