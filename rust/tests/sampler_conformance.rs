//! Statistical conformance harness for the sampler zoo: every backend —
//! exact spectral, MCMC (size-varying and fixed-size swap chains, plain
//! and constrained), and low-rank spectral projection — is tested against
//! brute-force enumeration of its target law on small kernels, via
//! chi-square goodness-of-fit over full subset histograms plus per-item
//! binomial marginal checks (see `tests/common/stats.rs`).
//!
//! All bounds are 4σ against fixed seeds (pinned in CI through
//! `KRONDPP_CONFORMANCE_SEED`), so the suite is deterministic: a failure
//! means the sampling distribution changed, not that the dice were
//! unlucky. The low-rank backend is checked against enumeration of its
//! *own* truncated kernel — it is an exact sampler of an approximate law;
//! its distance from the full law is a fidelity knob measured by
//! `benches/bench_sampler_zoo.rs`, not a conformance property.

mod common;

use common::stats::{
    chi_square_conformance, check_marginals, draw_many, empirical_marginals, seed, spd,
    subset_law,
};
use krondpp::dpp::{
    ConditionedSampler, Constraint, Kernel, LowRankBackend, McmcBackend, Sampler,
    SamplerBackend,
};
use krondpp::rng::Rng;
use std::collections::HashMap;

/// `N = 6` Kronecker kernel — small enough for exhaustive enumeration.
fn kron2() -> Kernel {
    Kernel::Kron2(spd(2, 41), spd(3, 42))
}

/// `N = 8` three-factor kernel (`m = 3` coverage).
fn kron3() -> Kernel {
    Kernel::Kron3(spd(2, 43), spd(2, 44), spd(2, 45))
}

/// Exact marginal of the enumerated law: `P(i ∈ Y) = Σ_{Y ∋ i} P(Y)`.
fn law_marginals(law: &HashMap<Vec<usize>, f64>, n: usize) -> Vec<f64> {
    let mut probs = vec![0.0; n];
    for (subset, &p) in law {
        for &i in subset {
            probs[i] += p;
        }
    }
    probs
}

#[test]
fn exact_sampler_matches_enumeration() {
    let kernel = kron2();
    let sampler = Sampler::new(&kernel).unwrap();
    let law = subset_law(&kernel, &Constraint::none(), None);
    let mut rng = Rng::new(seed());
    let draws = draw_many(&sampler, None, 6000, &mut rng);
    chi_square_conformance("exact/kron2", &draws, &law);
    check_marginals(
        "exact/kron2",
        &empirical_marginals(&draws, kernel.n()),
        &kernel.eigen().unwrap().inclusion_probabilities(),
        draws.len(),
    );
}

#[test]
fn exact_k_dpp_matches_enumeration_on_kron3() {
    let kernel = kron3();
    let sampler = Sampler::new(&kernel).unwrap();
    let law = subset_law(&kernel, &Constraint::none(), Some(3));
    let mut rng = Rng::new(seed() ^ 0xA1);
    let draws = draw_many(&sampler, Some(3), 6000, &mut rng);
    assert!(draws.iter().all(|y| y.len() == 3));
    chi_square_conformance("exact-k3/kron3", &draws, &law);
    check_marginals(
        "exact-k3/kron3",
        &empirical_marginals(&draws, kernel.n()),
        &law_marginals(&law, kernel.n()),
        draws.len(),
    );
}

#[test]
fn exact_constrained_sampler_matches_enumeration() {
    let kernel = kron2();
    let c = Constraint::new(vec![1], vec![4]).unwrap();
    let cs = ConditionedSampler::new(&kernel, c.clone()).unwrap();
    let law = subset_law(&kernel, &c, None);
    let mut rng = Rng::new(seed() ^ 0xA2);
    let draws = draw_many(&cs, None, 6000, &mut rng);
    assert!(draws.iter().all(|y| y.contains(&1) && !y.contains(&4)));
    chi_square_conformance("exact-cond/kron2", &draws, &law);

    // Constrained k-DPP over the same slate context.
    let law_k = subset_law(&kernel, &c, Some(3));
    let draws_k = draw_many(&cs, Some(3), 6000, &mut rng);
    chi_square_conformance("exact-cond-k3/kron2", &draws_k, &law_k);
}

#[test]
fn mcmc_chain_matches_enumeration() {
    let kernel = kron2();
    let backend = McmcBackend::new(&kernel, Constraint::none(), 400).unwrap();
    let law = subset_law(&kernel, &Constraint::none(), None);
    let mut rng = Rng::new(seed() ^ 0xB1);
    let draws = draw_many(&backend, None, 4000, &mut rng);
    chi_square_conformance("mcmc/kron2", &draws, &law);
    check_marginals(
        "mcmc/kron2",
        &empirical_marginals(&draws, kernel.n()),
        &law_marginals(&law, kernel.n()),
        draws.len(),
    );
}

#[test]
fn mcmc_swap_chain_matches_k_dpp_enumeration() {
    let kernel = kron2();
    let backend = McmcBackend::new(&kernel, Constraint::none(), 400).unwrap();
    let law = subset_law(&kernel, &Constraint::none(), Some(3));
    let mut rng = Rng::new(seed() ^ 0xB2);
    let draws = draw_many(&backend, Some(3), 4000, &mut rng);
    assert!(draws.iter().all(|y| y.len() == 3));
    chi_square_conformance("mcmc-k3/kron2", &draws, &law);
}

#[test]
fn mcmc_constrained_chains_match_conditional_enumeration() {
    let kernel = kron2();
    let c = Constraint::new(vec![0], vec![3]).unwrap();
    let backend = McmcBackend::new(&kernel, c.clone(), 400).unwrap();
    let mut rng = Rng::new(seed() ^ 0xB3);

    // Size-varying conditional chain (restricted proposals).
    let law = subset_law(&kernel, &c, None);
    let draws = draw_many(&backend, None, 4000, &mut rng);
    assert!(draws.iter().all(|y| y.contains(&0) && !y.contains(&3)));
    chi_square_conformance("mcmc-cond/kron2", &draws, &law);

    // Fixed-size swap chain under the same constraint.
    let law_k = subset_law(&kernel, &c, Some(3));
    let draws_k = draw_many(&backend, Some(3), 4000, &mut rng);
    assert!(draws_k.iter().all(|y| y.len() == 3 && y.contains(&0) && !y.contains(&3)));
    chi_square_conformance("mcmc-cond-k3/kron2", &draws_k, &law_k);
}

#[test]
fn mcmc_matches_enumeration_on_kron3() {
    let kernel = kron3();
    let backend = McmcBackend::new(&kernel, Constraint::none(), 500).unwrap();
    let law = subset_law(&kernel, &Constraint::none(), None);
    let mut rng = Rng::new(seed() ^ 0xB4);
    let draws = draw_many(&backend, None, 4000, &mut rng);
    chi_square_conformance("mcmc/kron3", &draws, &law);
}

#[test]
fn low_rank_backend_matches_its_truncated_law() {
    let kernel = kron2();
    let lr = LowRankBackend::new(&kernel, 4, Constraint::none()).unwrap();
    // The projection's own target law: enumeration of L_r, not L.
    let truncated = Kernel::Full(lr.truncated_dense());
    let law = subset_law(&truncated, &Constraint::none(), None);
    let mut rng = Rng::new(seed() ^ 0xC1);
    let draws = draw_many(&lr, None, 6000, &mut rng);
    assert!(draws.iter().all(|y| y.len() <= 4));
    chi_square_conformance("lowrank-r4/kron2", &draws, &law);

    let law_k = subset_law(&truncated, &Constraint::none(), Some(2));
    let draws_k = draw_many(&lr, Some(2), 6000, &mut rng);
    chi_square_conformance("lowrank-r4-k2/kron2", &draws_k, &law_k);
}

#[test]
fn low_rank_constrained_matches_truncated_conditional_law() {
    let kernel = kron2();
    let c = Constraint::new(vec![1], vec![4]).unwrap();
    let lr = LowRankBackend::new(&kernel, 4, c.clone()).unwrap();
    let truncated = Kernel::Full(lr.truncated_dense());
    let law = subset_law(&truncated, &c, None);
    let mut rng = Rng::new(seed() ^ 0xC2);
    let draws = draw_many(&lr, None, 6000, &mut rng);
    assert!(draws.iter().all(|y| y.contains(&1) && !y.contains(&4)));
    chi_square_conformance("lowrank-r4-cond/kron2", &draws, &law);
}

#[test]
fn full_rank_projection_matches_the_exact_law() {
    // At `rank = N` the projection *is* the kernel: conformance against
    // the full law, plus marginals against the factored diagonal table.
    let kernel = kron2();
    let n = kernel.n();
    let lr = LowRankBackend::new(&kernel, n, Constraint::none()).unwrap();
    let law = subset_law(&kernel, &Constraint::none(), None);
    let mut rng = Rng::new(seed() ^ 0xC3);
    let draws = draw_many(&lr, None, 6000, &mut rng);
    chi_square_conformance("lowrank-full/kron2", &draws, &law);
    check_marginals(
        "lowrank-full/kron2",
        &empirical_marginals(&draws, n),
        &kernel.eigen().unwrap().inclusion_probabilities(),
        draws.len(),
    );
}

#[test]
fn batch_engine_marginals_match_factored_inclusion_probabilities() {
    // The multi-threaded batch path (the serving engine) against the
    // factored marginal table on a bigger kernel — replaces the ad-hoc
    // marginal checks that used to live in the `dpp::sampler` unit tests.
    let kernel = Kernel::Kron2(spd(3, 46), spd(4, 47));
    let n = kernel.n();
    let sampler = Sampler::new(&kernel).unwrap();
    let count = 12_000;
    let draws = sampler.sample_batch(count, None, seed() ^ 0xD1);
    check_marginals(
        "batch/kron2-12",
        &empirical_marginals(&draws, n),
        &kernel.eigen().unwrap().inclusion_probabilities(),
        count,
    );
    // Expected size doubles as a scalar summary of the same law.
    let truth: f64 = kernel.eigen().unwrap().inclusion_probabilities().iter().sum();
    let mean: f64 = draws.iter().map(|y| y.len() as f64).sum::<f64>() / count as f64;
    assert!(
        (mean - truth).abs() < 0.1,
        "E|Y| = {mean:.3} vs factored diagonal sum {truth:.3}"
    );
}

#[test]
fn conformance_draws_are_deterministic_under_the_pinned_seed() {
    let kernel = kron2();
    let exact = Sampler::new(&kernel).unwrap();
    let mcmc = McmcBackend::new(&kernel, Constraint::none(), 50).unwrap();
    let lowrank = LowRankBackend::new(&kernel, 4, Constraint::none()).unwrap();
    let zoo: [(&str, &dyn SamplerBackend); 3] =
        [("exact", &exact), ("mcmc", &mcmc), ("lowrank", &lowrank)];
    for (name, backend) in zoo {
        let mut rng_a = Rng::new(seed());
        let mut rng_b = Rng::new(seed());
        let mut scratch_a = krondpp::dpp::SampleScratch::new();
        let mut scratch_b = krondpp::dpp::SampleScratch::new();
        let mut ya = Vec::new();
        let mut yb = Vec::new();
        for i in 0..50 {
            backend.draw_into(None, &mut rng_a, &mut scratch_a, &mut ya).unwrap();
            backend.draw_into(None, &mut rng_b, &mut scratch_b, &mut yb).unwrap();
            assert_eq!(ya, yb, "{name}: draw {i} diverged under identical seeds");
        }
    }
}
