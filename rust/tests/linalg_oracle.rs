//! Property-style oracle suite for the packed view-GEMM and the two-stage
//! symmetric eigensolver (the zero-copy linalg core's acceptance tests).
//!
//! The GEMM oracle is the naive triple loop evaluated directly over views
//! (so transposed and strided operands are checked without materializing
//! them); shapes sweep non-square, k = 1, 1×n, empty, MR/NR/KC edges and
//! random sizes. The eigensolver suite checks the blocked parallel path at
//! N = 257 (odd, exercising every panel remainder) for reconstruction,
//! orthogonality, agreement with the sequential path, and bitwise
//! determinism.

use krondpp::linalg::matmul::{self, GemmScratch};
use krondpp::linalg::{MatRef, Matrix, SymEigen};

/// Deterministic xorshift values in [-0.5, 0.5).
struct XorShift(u64);

impl XorShift {
    fn new(seed: u64) -> Self {
        XorShift(seed.wrapping_mul(0x9E3779B97F4A7C15) | 1)
    }
    fn next_f64(&mut self) -> f64 {
        self.0 ^= self.0 << 13;
        self.0 ^= self.0 >> 7;
        self.0 ^= self.0 << 17;
        (self.0 as f64 / u64::MAX as f64) - 0.5
    }
    fn next_in(&mut self, lo: usize, hi: usize) -> usize {
        self.0 ^= self.0 << 13;
        self.0 ^= self.0 >> 7;
        self.0 ^= self.0 << 17;
        lo + (self.0 % (hi - lo) as u64) as usize
    }
    fn matrix(&mut self, r: usize, c: usize) -> Matrix {
        Matrix::from_fn(r, c, |_, _| self.next_f64())
    }
}

/// The oracle: naive triple loop straight over views.
fn naive_views(a: MatRef<'_>, b: MatRef<'_>) -> Matrix {
    let (m, k) = a.shape();
    let n = b.cols();
    Matrix::from_fn(m, n, |i, j| (0..k).map(|l| a.get(i, l) * b.get(l, j)).sum())
}

fn check_pair(a: MatRef<'_>, b: MatRef<'_>, scratch: &mut GemmScratch, tag: &str) {
    let want = naive_views(a, b);
    let mut got = Matrix::zeros(a.rows(), b.cols());
    matmul::gemm_into(got.view_mut(), 1.0, a, b, false, scratch);
    let diff = got.rel_diff(&want);
    assert!(diff < 1e-11, "{tag}: rel diff {diff:.3e} at {:?}x{:?}", a.shape(), b.shape());
}

#[test]
fn gemm_oracle_randomized_shapes() {
    let mut rng = XorShift::new(1);
    let mut s = GemmScratch::new();
    // Hand-picked boundary shapes: (m, k, n).
    let fixed = [
        (1usize, 1usize, 1usize),
        (1, 300, 1),     // 1×n row-vector products
        (300, 1, 300),   // k = 1 outer products
        (8, 256, 4),     // exactly one register tile, one KC slab
        (9, 257, 5),     // every remainder at once
        (64, 64, 64),
        (129, 130, 131), // MC/KC straddling
        (200, 180, 190), // parallel path
    ];
    for (i, &(m, k, n)) in fixed.iter().enumerate() {
        let a = rng.matrix(m, k);
        let b = rng.matrix(k, n);
        check_pair(a.view(), b.view(), &mut s, &format!("fixed[{i}]"));
    }
    // Random non-square sweep.
    for round in 0..20 {
        let m = rng.next_in(1, 90);
        let k = rng.next_in(1, 90);
        let n = rng.next_in(1, 90);
        let a = rng.matrix(m, k);
        let b = rng.matrix(k, n);
        check_pair(a.view(), b.view(), &mut s, &format!("random[{round}]"));
    }
}

#[test]
fn gemm_oracle_empty_shapes() {
    let mut s = GemmScratch::new();
    let a = Matrix::zeros(0, 5);
    let b = Matrix::zeros(5, 4);
    let mut c = Matrix::zeros(0, 4);
    matmul::gemm_into(c.view_mut(), 1.0, a.view(), b.view(), false, &mut s);
    // k = 0: the product is exactly zero.
    let a = Matrix::zeros(3, 0);
    let b = Matrix::zeros(0, 2);
    let mut c = Matrix::filled(3, 2, 7.0);
    matmul::gemm_into(c.view_mut(), 1.0, a.view(), b.view(), false, &mut s);
    assert_eq!(c, Matrix::zeros(3, 2));
}

#[test]
fn gemm_oracle_transposed_views() {
    let mut rng = XorShift::new(2);
    let mut s = GemmScratch::new();
    for &(m, k, n) in &[(30usize, 40usize, 35usize), (170, 180, 175), (8, 3, 257)] {
        let at = rng.matrix(k, m); // stored transposed
        let bt = rng.matrix(n, k);
        let tag = format!("t-views {m}x{k}x{n}");
        // Aᵀ·B, A·Bᵀ, Aᵀ·Bᵀ all through transpose views.
        check_pair(at.view().t(), rng.matrix(k, n).view(), &mut s, &tag);
        check_pair(rng.matrix(m, k).view(), bt.view().t(), &mut s, &tag);
        check_pair(at.view().t(), bt.view().t(), &mut s, &tag);
    }
}

#[test]
fn gemm_oracle_strided_subblocks() {
    let mut rng = XorShift::new(3);
    let mut s = GemmScratch::new();
    let big_a = rng.matrix(260, 270);
    let big_b = rng.matrix(270, 240);
    for &(i0, j0, m, k, n) in
        &[(0usize, 0usize, 50usize, 60usize, 40usize), (3, 7, 130, 200, 140), (255, 1, 5, 269, 239)]
    {
        let av = big_a.view().submatrix(i0, j0, m, k);
        let bv = big_b.view().submatrix(j0, i0.min(1), k, n);
        check_pair(av, bv, &mut s, &format!("strided ({i0},{j0}) {m}x{k}x{n}"));
        // A strided sub-block, transposed on top.
        check_pair(av.t(), big_a.view().submatrix(i0, j0, m, n.min(m)), &mut s, "strided-t");
    }
}

#[test]
fn gemm_matches_public_wrappers() {
    // The convenience wrappers (thread-local scratch) agree bitwise with
    // explicit-scratch calls.
    let mut rng = XorShift::new(4);
    let a = rng.matrix(150, 140);
    let b = rng.matrix(140, 160);
    let c1 = matmul::matmul(&a, &b).unwrap();
    let mut c2 = Matrix::zeros(150, 160);
    matmul::gemm_into(c2.view_mut(), 1.0, a.view(), b.view(), false, &mut GemmScratch::new());
    assert_eq!(c1.as_slice(), c2.as_slice());
}

fn spd(n: usize, seed: u64) -> Matrix {
    let mut rng = XorShift::new(seed);
    let x = rng.matrix(n, n);
    let mut g = matmul::matmul_nt(&x, &x).unwrap();
    g.add_diag_mut(0.5);
    g
}

#[test]
fn sym_eigen_257_reconstruction_and_orthogonality() {
    // N = 257: odd, not a multiple of the panel width — every block
    // remainder path in the two-stage solver is exercised.
    let a = spd(257, 11);
    let eig = SymEigen::new(&a).unwrap();
    let rec = eig.reconstruct();
    let rec_err = rec.rel_diff(&a);
    assert!(rec_err < 1e-10, "reconstruction error {rec_err:.3e}");
    let vtv = matmul::matmul_tn(&eig.vectors, &eig.vectors).unwrap();
    let orth_err = vtv.rel_diff(&Matrix::identity(257));
    assert!(orth_err < 1e-10, "orthogonality error {orth_err:.3e}");
    // Ascending eigenvalues.
    for w in eig.values.windows(2) {
        assert!(w[0] <= w[1]);
    }
}

#[test]
fn sym_eigen_blocked_matches_sequential_at_257() {
    let a = spd(257, 12);
    let blocked = SymEigen::new_blocked(&a).unwrap();
    let seq = SymEigen::new_seq(&a).unwrap();
    let scale = seq.values.last().unwrap().abs().max(1.0);
    for (p, q) in blocked.values.iter().zip(&seq.values) {
        assert!((p - q).abs() / scale < 1e-12, "{p} vs {q}");
    }
    // Both reconstruct the same matrix to ≤ 1e-10.
    assert!(blocked.reconstruct().rel_diff(&seq.reconstruct()) < 1e-10);
}

#[test]
fn sym_eigen_blocked_bitwise_deterministic() {
    // Fixed thread count (same process): repeated decompositions must be
    // bit-for-bit identical — the GEMM accumulation order and the rotation
    // replay are both partition-invariant.
    let a = spd(257, 13);
    let e1 = SymEigen::new_blocked(&a).unwrap();
    let e2 = SymEigen::new_blocked(&a).unwrap();
    let e3 = SymEigen::new(&a).unwrap(); // auto path dispatches blocked here
    assert_eq!(e1.values, e2.values);
    assert_eq!(e1.vectors.as_slice(), e2.vectors.as_slice());
    assert_eq!(e1.values, e3.values);
    assert_eq!(e1.vectors.as_slice(), e3.vectors.as_slice());
}
