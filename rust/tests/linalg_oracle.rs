//! Property-style oracle suite for the packed view-GEMM and the two-stage
//! symmetric eigensolver (the zero-copy linalg core's acceptance tests).
//!
//! The GEMM oracle is the naive triple loop evaluated directly over views
//! (so transposed and strided operands are checked without materializing
//! them); shapes sweep non-square, k = 1, 1×n, empty, MR/NR/KC edges and
//! random sizes. The eigensolver suite checks the blocked parallel path at
//! N = 257 (odd, exercising every panel remainder) for reconstruction,
//! orthogonality, agreement with the sequential path, and bitwise
//! determinism.

use krondpp::dpp::elementary::ElementaryTable;
use krondpp::linalg::matmul::{self, GemmScratch};
use krondpp::linalg::simd;
use krondpp::linalg::{trisolve, MatRef, Matrix, SymEigen};

/// Deterministic xorshift values in [-0.5, 0.5).
struct XorShift(u64);

impl XorShift {
    fn new(seed: u64) -> Self {
        XorShift(seed.wrapping_mul(0x9E3779B97F4A7C15) | 1)
    }
    fn next_f64(&mut self) -> f64 {
        self.0 ^= self.0 << 13;
        self.0 ^= self.0 >> 7;
        self.0 ^= self.0 << 17;
        (self.0 as f64 / u64::MAX as f64) - 0.5
    }
    fn next_in(&mut self, lo: usize, hi: usize) -> usize {
        self.0 ^= self.0 << 13;
        self.0 ^= self.0 >> 7;
        self.0 ^= self.0 << 17;
        lo + (self.0 % (hi - lo) as u64) as usize
    }
    fn matrix(&mut self, r: usize, c: usize) -> Matrix {
        Matrix::from_fn(r, c, |_, _| self.next_f64())
    }
}

/// The oracle: naive triple loop straight over views.
fn naive_views(a: MatRef<'_>, b: MatRef<'_>) -> Matrix {
    let (m, k) = a.shape();
    let n = b.cols();
    Matrix::from_fn(m, n, |i, j| (0..k).map(|l| a.get(i, l) * b.get(l, j)).sum())
}

fn check_pair(a: MatRef<'_>, b: MatRef<'_>, scratch: &mut GemmScratch, tag: &str) {
    let want = naive_views(a, b);
    let mut got = Matrix::zeros(a.rows(), b.cols());
    matmul::gemm_into(got.view_mut(), 1.0, a, b, false, scratch);
    let diff = got.rel_diff(&want);
    assert!(diff < 1e-11, "{tag}: rel diff {diff:.3e} at {:?}x{:?}", a.shape(), b.shape());
}

#[test]
fn gemm_oracle_randomized_shapes() {
    let mut rng = XorShift::new(1);
    let mut s = GemmScratch::new();
    // Hand-picked boundary shapes: (m, k, n).
    let fixed = [
        (1usize, 1usize, 1usize),
        (1, 300, 1),     // 1×n row-vector products
        (300, 1, 300),   // k = 1 outer products
        (8, 256, 4),     // exactly one register tile, one KC slab
        (9, 257, 5),     // every remainder at once
        (64, 64, 64),
        (129, 130, 131), // MC/KC straddling
        (200, 180, 190), // parallel path
    ];
    for (i, &(m, k, n)) in fixed.iter().enumerate() {
        let a = rng.matrix(m, k);
        let b = rng.matrix(k, n);
        check_pair(a.view(), b.view(), &mut s, &format!("fixed[{i}]"));
    }
    // Random non-square sweep.
    for round in 0..20 {
        let m = rng.next_in(1, 90);
        let k = rng.next_in(1, 90);
        let n = rng.next_in(1, 90);
        let a = rng.matrix(m, k);
        let b = rng.matrix(k, n);
        check_pair(a.view(), b.view(), &mut s, &format!("random[{round}]"));
    }
}

#[test]
fn gemm_oracle_empty_shapes() {
    let mut s = GemmScratch::new();
    let a = Matrix::zeros(0, 5);
    let b = Matrix::zeros(5, 4);
    let mut c = Matrix::zeros(0, 4);
    matmul::gemm_into(c.view_mut(), 1.0, a.view(), b.view(), false, &mut s);
    // k = 0: the product is exactly zero.
    let a = Matrix::zeros(3, 0);
    let b = Matrix::zeros(0, 2);
    let mut c = Matrix::filled(3, 2, 7.0);
    matmul::gemm_into(c.view_mut(), 1.0, a.view(), b.view(), false, &mut s);
    assert_eq!(c, Matrix::zeros(3, 2));
}

#[test]
fn gemm_oracle_transposed_views() {
    let mut rng = XorShift::new(2);
    let mut s = GemmScratch::new();
    for &(m, k, n) in &[(30usize, 40usize, 35usize), (170, 180, 175), (8, 3, 257)] {
        let at = rng.matrix(k, m); // stored transposed
        let bt = rng.matrix(n, k);
        let tag = format!("t-views {m}x{k}x{n}");
        // Aᵀ·B, A·Bᵀ, Aᵀ·Bᵀ all through transpose views.
        check_pair(at.view().t(), rng.matrix(k, n).view(), &mut s, &tag);
        check_pair(rng.matrix(m, k).view(), bt.view().t(), &mut s, &tag);
        check_pair(at.view().t(), bt.view().t(), &mut s, &tag);
    }
}

#[test]
fn gemm_oracle_strided_subblocks() {
    let mut rng = XorShift::new(3);
    let mut s = GemmScratch::new();
    let big_a = rng.matrix(260, 270);
    let big_b = rng.matrix(270, 240);
    for &(i0, j0, m, k, n) in
        &[(0usize, 0usize, 50usize, 60usize, 40usize), (3, 7, 130, 200, 140), (255, 1, 5, 269, 239)]
    {
        let av = big_a.view().submatrix(i0, j0, m, k);
        let bv = big_b.view().submatrix(j0, i0.min(1), k, n);
        check_pair(av, bv, &mut s, &format!("strided ({i0},{j0}) {m}x{k}x{n}"));
        // A strided sub-block, transposed on top.
        check_pair(av.t(), big_a.view().submatrix(i0, j0, m, n.min(m)), &mut s, "strided-t");
    }
}

#[test]
fn gemm_matches_public_wrappers() {
    // The convenience wrappers (thread-local scratch) agree bitwise with
    // explicit-scratch calls.
    let mut rng = XorShift::new(4);
    let a = rng.matrix(150, 140);
    let b = rng.matrix(140, 160);
    let c1 = matmul::matmul(&a, &b).unwrap();
    let mut c2 = Matrix::zeros(150, 160);
    matmul::gemm_into(c2.view_mut(), 1.0, a.view(), b.view(), false, &mut GemmScratch::new());
    assert_eq!(c1.as_slice(), c2.as_slice());
}

fn spd(n: usize, seed: u64) -> Matrix {
    let mut rng = XorShift::new(seed);
    let x = rng.matrix(n, n);
    let mut g = matmul::matmul_nt(&x, &x).unwrap();
    g.add_diag_mut(0.5);
    g
}

#[test]
fn sym_eigen_257_reconstruction_and_orthogonality() {
    // N = 257: odd, not a multiple of the panel width — every block
    // remainder path in the two-stage solver is exercised.
    let a = spd(257, 11);
    let eig = SymEigen::new(&a).unwrap();
    let rec = eig.reconstruct();
    let rec_err = rec.rel_diff(&a);
    assert!(rec_err < 1e-10, "reconstruction error {rec_err:.3e}");
    let vtv = matmul::matmul_tn(&eig.vectors, &eig.vectors).unwrap();
    let orth_err = vtv.rel_diff(&Matrix::identity(257));
    assert!(orth_err < 1e-10, "orthogonality error {orth_err:.3e}");
    // Ascending eigenvalues.
    for w in eig.values.windows(2) {
        assert!(w[0] <= w[1]);
    }
}

#[test]
fn sym_eigen_blocked_matches_sequential_at_257() {
    let a = spd(257, 12);
    let blocked = SymEigen::new_blocked(&a).unwrap();
    let seq = SymEigen::new_seq(&a).unwrap();
    let scale = seq.values.last().unwrap().abs().max(1.0);
    for (p, q) in blocked.values.iter().zip(&seq.values) {
        assert!((p - q).abs() / scale < 1e-12, "{p} vs {q}");
    }
    // Both reconstruct the same matrix to ≤ 1e-10.
    assert!(blocked.reconstruct().rel_diff(&seq.reconstruct()) < 1e-10);
}

// ---------------------------------------------------------------------------
// Dispatch-arm conformance: forced-scalar oracle vs the detected kernel
// ---------------------------------------------------------------------------
//
// `simd::forced_scalar()` is the reference arm; `simd::active()` is whatever
// runtime detection picked (AVX2+FMA, NEON, or scalar again). The contract is
// *bitwise* agreement — the vector kernels reproduce the scalar arm's exact
// rounding and reduction order — so every assertion below is `assert_eq` on
// raw f64 slices, never a tolerance. On hardware where `active()` resolves to
// scalar these tests degenerate to self-comparison and still pass; CI's
// x86_64 and aarch64 jobs exercise the real vector arms.

fn check_pair_bitwise(a: MatRef<'_>, b: MatRef<'_>, scratch: &mut GemmScratch, tag: &str) {
    let (m, n) = (a.rows(), b.cols());
    let mut got = Matrix::zeros(m, n);
    let mut want = Matrix::zeros(m, n);
    matmul::gemm_into_with(got.view_mut(), 1.0, a, b, false, scratch, simd::active());
    matmul::gemm_into_with(want.view_mut(), 1.0, a, b, false, scratch, simd::forced_scalar());
    assert_eq!(got.as_slice(), want.as_slice(), "{tag}: dispatch arm changed GEMM bits");
}

#[test]
fn dispatched_gemm_agrees_bitwise_with_scalar_oracle() {
    let mut rng = XorShift::new(21);
    let mut s = GemmScratch::new();
    // Shapes chosen so every arm hits its remainder tiles: 63 ≡ MR−1 for
    // both the 8-row and 4-row kernels; 59 ≡ NR−1 mod 4 and mod 12, and
    // 59 ≡ 5 mod 6 for NEON; k = 257 straddles the KC = 256 slab edge;
    // (511, 1, 251) is a k = 1 outer product big enough for the packed
    // path; the last shape crosses MC and runs multi-threaded.
    let shapes = [
        (63usize, 257usize, 59usize),
        (63, 64, 11),
        (511, 1, 251),
        (130, 300, 131),
        (200, 180, 190),
    ];
    for (i, &(m, k, n)) in shapes.iter().enumerate() {
        let a = rng.matrix(m, k);
        let b = rng.matrix(k, n);
        check_pair_bitwise(a.view(), b.view(), &mut s, &format!("shape[{i}] {m}x{k}x{n}"));
    }
    // Strided + transposed views through the same packed path.
    let big = rng.matrix(140, 150);
    let av = big.view().submatrix(3, 5, 96, 130);
    let bv = big.view().submatrix(1, 2, 130, 96);
    check_pair_bitwise(av, bv, &mut s, "strided");
    let at = rng.matrix(120, 125);
    check_pair_bitwise(at.view().t(), rng.matrix(120, 123).view(), &mut s, "transposed");
}

#[test]
fn dispatched_sweeps_agree_bitwise_with_scalar_oracle() {
    // Every flat op, over lengths covering 0, every lane remainder for
    // 2-/4-wide vectors, and past the wrapper's inline-scalar gate; data
    // offset by 1 so slices are deliberately unaligned.
    let act = simd::active();
    let ora = simd::forced_scalar();
    let mut rng = XorShift::new(22);
    let data: Vec<f64> = (0..600).map(|_| rng.next_f64()).collect();
    let weights: Vec<f64> = (0..600).map(|_| rng.next_f64() * 4.0 - 1.0).collect();
    for len in (0usize..=9).chain([15, 16, 17, 63, 64, 65, 66, 67, 130, 259]) {
        let a = &data[1..1 + len];
        let b = &data[len + 2..2 * len + 2];
        let w = &weights[1..1 + len];
        assert_eq!(act.dot(a, b).to_bits(), ora.dot(a, b).to_bits(), "dot len {len}");
        assert_eq!(
            act.weighted_sumsq(w, a).to_bits(),
            ora.weighted_sumsq(w, a).to_bits(),
            "weighted_sumsq len {len}"
        );
        let (mut y1, mut y2) = (a.to_vec(), a.to_vec());
        act.axpy(&mut y1, -1.75, b);
        ora.axpy(&mut y2, -1.75, b);
        assert_eq!(y1, y2, "axpy len {len}");
        act.scale(&mut y1, 0.3);
        ora.scale(&mut y2, 0.3);
        assert_eq!(y1, y2, "scale len {len}");
        act.div_assign(&mut y1, 0.7);
        ora.div_assign(&mut y2, 0.7);
        assert_eq!(y1, y2, "div len {len}");
        let (mut o1, mut o2) = (vec![0.0; len], vec![0.0; len]);
        act.mul_into(&mut o1, a, b);
        ora.mul_into(&mut o2, a, b);
        assert_eq!(o1, o2, "mul_into len {len}");
        act.square_into(&mut o1, a);
        ora.square_into(&mut o2, a);
        assert_eq!(o1, o2, "square_into len {len}");
        act.marginal_weights(&mut o1, w);
        ora.marginal_weights(&mut o2, w);
        assert_eq!(o1, o2, "marginal_weights len {len}");
        act.dp_row(&mut o1, a, 1.37);
        ora.dp_row(&mut o2, a, 1.37);
        assert_eq!(o1, o2, "dp_row len {len}");
    }
}

#[test]
fn dispatched_trisolve_agrees_bitwise_with_scalar_oracle() {
    let mut rng = XorShift::new(23);
    // 67 RHS columns: past the sweeps' vector widths with a remainder.
    let n = 80;
    let mut l = rng.matrix(n, n);
    for i in 0..n {
        for j in (i + 1)..n {
            l.set(i, j, 0.0);
        }
        let d = l.get(i, i).abs() + 1.0;
        l.set(i, i, d);
    }
    let b = rng.matrix(n, 67);
    for unit in [false, true] {
        let mut x1 = b.clone();
        let mut x2 = b.clone();
        trisolve::solve_lower_in_place_with(l.view(), &mut x1, unit, simd::active());
        trisolve::solve_lower_in_place_with(l.view(), &mut x2, unit, simd::forced_scalar());
        assert_eq!(x1.as_slice(), x2.as_slice(), "lower unit={unit}");
        let mut u1 = b.clone();
        let mut u2 = b.clone();
        trisolve::solve_upper_in_place_with(l.view().t(), &mut u1, unit, simd::active());
        trisolve::solve_upper_in_place_with(l.view().t(), &mut u2, unit, simd::forced_scalar());
        assert_eq!(u1.as_slice(), u2.as_slice(), "upper unit={unit}");
    }
}

#[test]
fn dispatched_dp_table_agrees_bitwise_with_scalar_oracle() {
    // The full elementary-polynomial DP (row sweep + overflow rescale):
    // a long spectrum with growth forcing the rescale branch, and k values
    // hitting both the sub-row and full-row regimes.
    let lambda: Vec<f64> = (0..500).map(|i| 1.0 + ((i * 37) % 97) as f64 * 3.0).collect();
    for k in [1usize, 7, 64, 200] {
        let t1 = ElementaryTable::new_with(&lambda, k, simd::active());
        let t2 = ElementaryTable::new_with(&lambda, k, simd::forced_scalar());
        for n in 0..=lambda.len() {
            for j in 0..=k {
                assert_eq!(
                    t1.log_e(n, j).to_bits(),
                    t2.log_e(n, j).to_bits(),
                    "log_e({n},{j}) k={k}"
                );
            }
        }
    }
}

#[test]
fn dispatched_marginal_diagonals_agree_bitwise_with_scalar_oracle() {
    use krondpp::dpp::{Kernel, MarginalScratch};
    let mut rng = XorShift::new(24);
    let spd_small = |n: usize, rng: &mut XorShift| {
        let x = rng.matrix(n, n);
        let mut g = matmul::matmul_nt(&x, &x).unwrap();
        g.add_diag_mut(0.5);
        g
    };
    let k1 = spd_small(17, &mut rng);
    let k2 = spd_small(23, &mut rng);
    let k3 = spd_small(5, &mut rng);
    for kernel in [
        Kernel::Full(spd_small(60, &mut rng)),
        Kernel::Kron2(k1.clone(), k2.clone()),
        Kernel::Kron3(k1, k2, k3),
    ] {
        let eig = kernel.eigen().unwrap();
        let (mut o1, mut o2) = (Vec::new(), Vec::new());
        let mut s = MarginalScratch::new();
        eig.inclusion_probabilities_into_with(&mut o1, &mut s, simd::active());
        eig.inclusion_probabilities_into_with(&mut o2, &mut s, simd::forced_scalar());
        assert_eq!(o1, o2, "marginal diagonal changed bits across dispatch arms");
    }
}

#[test]
fn sym_eigen_blocked_bitwise_deterministic() {
    // Fixed thread count (same process): repeated decompositions must be
    // bit-for-bit identical — the GEMM accumulation order and the rotation
    // replay are both partition-invariant.
    let a = spd(257, 13);
    let e1 = SymEigen::new_blocked(&a).unwrap();
    let e2 = SymEigen::new_blocked(&a).unwrap();
    let e3 = SymEigen::new(&a).unwrap(); // auto path dispatches blocked here
    assert_eq!(e1.values, e2.values);
    assert_eq!(e1.vectors.as_slice(), e2.vectors.as_slice());
    assert_eq!(e1.values, e3.values);
    assert_eq!(e1.vectors.as_slice(), e3.vectors.as_slice());
}
