//! Integration: AOT-compiled HLO artifacts vs the pure-Rust implementations
//! of the same math. This is the cross-layer correctness contract — the
//! JAX/Pallas kernels (already validated against `ref.py` by pytest) must
//! agree with the Rust `linalg::kron` contractions to f64 precision once
//! round-tripped through PJRT.
//!
//! Requires `make artifacts`; tests are skipped (with a notice) otherwise
//! so plain `cargo test` still passes in a fresh checkout.

use krondpp::learn::krk::Contractions;
use krondpp::linalg::{kron, matmul, Matrix};
use krondpp::rng::Rng;
use krondpp::runtime::{Engine, HloContractions};

fn engine_or_skip() -> Option<Engine> {
    match Engine::load_default() {
        Ok(e) => Some(e),
        Err(err) => {
            eprintln!("skipping runtime parity tests: {err}");
            None
        }
    }
}

fn rnd(n: usize, m: usize, seed: u64) -> Matrix {
    let mut rng = Rng::new(seed);
    rng.normal_matrix(n, m)
}

#[test]
fn krk_contractions_artifact_matches_rust() {
    let Some(engine) = engine_or_skip() else { return };
    for &(n1, n2) in &[(8usize, 8usize), (16, 16)] {
        let name = format!("krk_contractions_{n1}x{n2}");
        if !engine.has(&name) {
            continue;
        }
        let theta = rnd(n1 * n2, n1 * n2, 1);
        let l1 = rnd(n1, n1, 2);
        let l2 = rnd(n2, n2, 3);
        let out = engine.execute_matrices(&name, &[&theta, &l1, &l2]).unwrap();
        assert_eq!(out.len(), 2);
        let a1_rust = kron::block_trace(&theta, &l2, n1, n2).unwrap();
        let a2_rust = kron::weighted_block_sum(&theta, &l1, n1, n2).unwrap();
        assert!(
            out[0].rel_diff(&a1_rust) < 1e-11,
            "A1 mismatch at {n1}x{n2}: {}",
            out[0].rel_diff(&a1_rust)
        );
        assert!(
            out[1].rel_diff(&a2_rust) < 1e-11,
            "A2 mismatch at {n1}x{n2}: {}",
            out[1].rel_diff(&a2_rust)
        );
    }
}

#[test]
fn krk_term_artifacts_match_rust_sandwiches() {
    let Some(engine) = engine_or_skip() else { return };
    let (n1, n2) = (8usize, 8usize);
    if !engine.has("krk_l1_term_8x8") {
        return;
    }
    let theta = rnd(n1 * n2, n1 * n2, 4);
    let l1 = rnd(n1, n1, 5);
    let l2 = rnd(n2, n2, 6);
    let t1 = engine.execute_matrices("krk_l1_term_8x8", &[&theta, &l1, &l2]).unwrap();
    let a1 = kron::block_trace(&theta, &l2, n1, n2).unwrap();
    let want1 = matmul::sandwich(&l1, &a1, &l1).unwrap();
    assert!(t1[0].rel_diff(&want1) < 1e-11);

    let t2 = engine.execute_matrices("krk_l2_term_8x8", &[&theta, &l1, &l2]).unwrap();
    let a2 = kron::weighted_block_sum(&theta, &l1, n1, n2).unwrap();
    let want2 = matmul::sandwich(&l2, &a2, &l2).unwrap();
    assert!(t2[0].rel_diff(&want2) < 1e-11);
}

#[test]
fn gram_artifact_matches_rust() {
    let Some(engine) = engine_or_skip() else { return };
    if !engine.has("gram_256x64") {
        return;
    }
    let x = rnd(256, 64, 7);
    let out = engine.execute_matrices("gram_256x64", &[&x]).unwrap();
    let want = matmul::matmul_tn(&x, &x).unwrap();
    assert!(out[0].rel_diff(&want) < 1e-11, "gram mismatch {}", out[0].rel_diff(&want));
}

#[test]
fn picard_ldl_artifact_matches_rust() {
    let Some(engine) = engine_or_skip() else { return };
    if !engine.has("picard_ldl_64") {
        return;
    }
    let l = rnd(64, 64, 8);
    let delta = rnd(64, 64, 9);
    let out = engine.execute_matrices("picard_ldl_64", &[&l, &delta]).unwrap();
    let ldl = matmul::sandwich(&l, &delta, &l).unwrap();
    let mut want = l.clone();
    want += &ldl;
    assert!(out[0].rel_diff(&want) < 1e-11);
}

#[test]
fn kron_inv_action_matches_dense_solve() {
    let Some(engine) = engine_or_skip() else { return };
    if !engine.has("kron_inv_action_8x8") {
        return;
    }
    let (n1, n2) = (8usize, 8usize);
    let mut rng = Rng::new(10);
    let l1 = {
        let mut m = rng.paper_init_kernel(n1);
        m.scale_mut(1.0 / n1 as f64);
        m.add_diag_mut(0.3);
        m
    };
    let l2 = {
        let mut m = rng.paper_init_kernel(n2);
        m.scale_mut(1.0 / n2 as f64);
        m.add_diag_mut(0.3);
        m
    };
    let e1 = krondpp::linalg::SymEigen::new(&l1).unwrap();
    let e2 = krondpp::linalg::SymEigen::new(&l2).unwrap();
    let rhs: Vec<f64> = (0..n1 * n2).map(|i| (i as f64 * 0.37).sin()).collect();
    let out = engine
        .execute(
            "kron_inv_action_8x8",
            &[
                e1.vectors.as_slice(),
                e2.vectors.as_slice(),
                &e1.values,
                &e2.values,
                &rhs,
            ],
        )
        .unwrap();
    // Dense check: (I + L1⊗L2)^{-1} rhs.
    let mut dense = kron::kron(&l1, &l2);
    dense.add_diag_mut(1.0);
    let want = krondpp::linalg::Cholesky::factor(&dense).unwrap().solve_vec(&rhs).unwrap();
    for (p, q) in out[0].iter().zip(&want) {
        assert!((p - q).abs() < 1e-9, "{p} vs {q}");
    }
}

#[test]
fn hlo_contractions_backend_drop_in() {
    // The HLO backend must be usable inside KrkPicard and agree with CPU.
    let Some(engine) = engine_or_skip() else { return };
    let backend = HloContractions::new(engine);
    if !backend.supports(8, 8) {
        return;
    }
    let theta = rnd(64, 64, 11);
    let l2 = rnd(8, 8, 12);
    let w = rnd(8, 8, 13);
    let a1 = backend.block_trace(&theta, &l2, 8, 8).unwrap();
    let a1_cpu = kron::block_trace(&theta, &l2, 8, 8).unwrap();
    assert!(a1.rel_diff(&a1_cpu) < 1e-11);
    let a2 = backend.weighted_block_sum(&theta, &w, 8, 8).unwrap();
    let a2_cpu = kron::weighted_block_sum(&theta, &w, 8, 8).unwrap();
    assert!(a2.rel_diff(&a2_cpu) < 1e-11);
}

#[test]
fn engine_validates_shapes() {
    let Some(engine) = engine_or_skip() else { return };
    if !engine.has("gram_256x64") {
        return;
    }
    let wrong = rnd(4, 4, 14);
    let err = engine.execute_matrices("gram_256x64", &[&wrong]).unwrap_err();
    assert!(err.to_string().contains("shape") || err.to_string().contains("elems"));
    assert!(engine.execute("no_such_artifact", &[]).is_err());
}
