//! Deterministic fault-injection chaos suite (requires the
//! `fault-injection` feature; CI pins `KRONDPP_FAULT_SEED`).
//!
//! Every test drives the live coordinator with a seeded
//! [`FaultPlan`] whose budgets fire an exact number of times, then
//! checks the fault-tolerance invariants end to end:
//!
//! - every accepted request reaches exactly one definitive outcome
//!   (`accepted = completed + failed + rejected_invalid +
//!   deadline_exceeded`, globally and per tenant);
//! - poisoned publishes are quarantined without touching the serving
//!   epoch, and `rollback` restores a historical generation;
//! - injected primary-path failures trip the circuit breaker, are
//!   absorbed by the degraded-mode fallback chain, and the breaker
//!   recovers through half-open probes once the fault budget drains;
//! - a worker panic fails only its own coalesced group, other tenants
//!   never observe it, and the supervisor respawns the worker;
//! - injected serve stalls blow request budgets into `Deadline`
//!   errors, never into hangs or silent drops — including when the
//!   budget arrives over the TCP wire and the client vanishes
//!   mid-flight;
//! - shutdown completes cleanly after all of the above.

use krondpp::config::{FallbackPolicy, ServiceConfig};
use krondpp::coordinator::faults::FaultPlan;
use krondpp::coordinator::{
    DppService, KernelRegistry, NetConfig, NetServer, SampleRequest, TenantId, WireClient,
};
use krondpp::data;
use krondpp::dpp::{Kernel, KernelDelta, SampleMode};
use krondpp::error::ErrorKind;
use krondpp::rng::Rng;
use krondpp::ser::wire::{WireRequest, DEFAULT_MAX_FRAME};
use krondpp::Error;
use std::io::Write as _;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

fn kernel(n1: usize, n2: usize, seed: u64) -> Kernel {
    let mut rng = Rng::new(seed);
    data::paper_truth_kernel(n1, n2, &mut rng)
}

/// A factored kernel with one non-finite entry — the registry
/// validator must quarantine it.
fn poisoned(n1: usize, n2: usize, seed: u64) -> Kernel {
    let mut k = kernel(n1, n2, seed);
    match &mut k {
        Kernel::Kron2(_, b) => b.set(0, 1, f64::NAN),
        _ => panic!("paper_truth_kernel returns Kron2"),
    }
    k
}

/// Poll `cond` until it holds or `ms` elapse (respawns are
/// asynchronous: the supervisor books them after the panicking worker
/// has already answered its clients).
fn wait_for(ms: u64, mut cond: impl FnMut() -> bool) -> bool {
    let deadline = Instant::now() + Duration::from_millis(ms);
    while Instant::now() < deadline {
        if cond() {
            return true;
        }
        std::thread::sleep(Duration::from_millis(5));
    }
    cond()
}

fn draw(svc: &DppService, t: TenantId, k: usize) -> Result<Vec<usize>, Error> {
    svc.submit(SampleRequest::for_tenant(t, k))?.wait()
}

/// Poisoned publishes are quarantined without disturbing the serving
/// epoch; `rollback` then restores a historical generation and the
/// tenant keeps serving across the whole sequence.
#[test]
fn poisoned_publish_is_quarantined_and_rollback_restores_service() {
    let reg = Arc::new(KernelRegistry::with_history(0, 4));
    let t = reg.add_tenant("alpha", &kernel(4, 4, 11)).unwrap();
    let cfg = ServiceConfig {
        workers: 2,
        max_batch: 8,
        batch_window_us: 50,
        ..ServiceConfig::default()
    };
    let svc = DppService::start_with_registry(Arc::clone(&reg), &cfg, 12).unwrap();
    let entry = reg.entry(t).unwrap();

    assert_eq!(draw(&svc, t, 3).unwrap().len(), 3);
    let g0 = entry.generation();

    // A healthy refresh advances the generation.
    reg.publish(t, &kernel(4, 4, 13)).unwrap();
    let g1 = entry.generation();
    assert!(g1 > g0);
    assert_eq!(draw(&svc, t, 3).unwrap().len(), 3);

    // A poisoned refresh is quarantined: error surfaced, generation
    // untouched, serving unaffected.
    let err = reg.publish(t, &poisoned(4, 4, 14)).unwrap_err();
    assert!(err.to_string().contains("non-finite"), "unexpected quarantine reason: {err}");
    assert_eq!(reg.quarantines(), 1);
    assert_eq!(entry.quarantined_candidates(), 1);
    assert!(entry.last_quarantine().unwrap().contains("non-finite"));
    assert_eq!(entry.generation(), g1);
    assert_eq!(draw(&svc, t, 4).unwrap().len(), 4);

    // Roll back to the pre-refresh kernel: new generation, still serving.
    let g2 = svc.rollback(t, g0).unwrap();
    assert!(g2 > g1);
    assert_eq!(reg.rollbacks(), 1);
    assert_eq!(draw(&svc, t, 3).unwrap().len(), 3);

    let m = svc.metrics();
    assert_eq!(m.completed.load(Ordering::Relaxed), 4);
    assert_eq!(m.failed.load(Ordering::Relaxed), 0);
    svc.shutdown();
}

/// A poisoned delta (non-finite perturbation) is quarantined exactly like
/// a poisoned full publish: error surfaced, generation and serving epoch
/// untouched, the churn ledger records no publication, and the tenant
/// keeps serving; healthy deltas before and after still absorb
/// incrementally.
#[test]
fn poisoned_delta_is_quarantined_and_epoch_survives() {
    let reg = Arc::new(KernelRegistry::with_history(0, 4));
    let t = reg.add_tenant("alpha", &kernel(8, 4, 61)).unwrap();
    let cfg = ServiceConfig {
        workers: 2,
        max_batch: 8,
        batch_window_us: 50,
        ..ServiceConfig::default()
    };
    let svc = DppService::start_with_registry(Arc::clone(&reg), &cfg, 62).unwrap();
    let entry = reg.entry(t).unwrap();
    assert_eq!(draw(&svc, t, 3).unwrap().len(), 3);
    let g0 = entry.generation();

    // Healthy rank-1 feedback delta: incremental secular refresh.
    let mut rng = Rng::new(63);
    let good = KernelDelta::Perturb {
        side: 0,
        rhos: vec![1.0],
        vectors: rng.uniform_matrix(8, 1, -0.05, 0.05),
    };
    let out = svc.publish_delta(t, &good).unwrap();
    assert!(out.incremental, "rank 1 ≤ n/4 must absorb incrementally");
    assert_eq!(out.generation, g0 + 1);
    assert_eq!(draw(&svc, t, 3).unwrap().len(), 3);

    // Input poisoning: a NaN perturbation vector is screened out before
    // any state or counter moves.
    let mut bad_vectors = rng.uniform_matrix(8, 1, -0.05, 0.05);
    bad_vectors.set(2, 0, f64::NAN);
    let bad = KernelDelta::Perturb { side: 0, rhos: vec![1.0], vectors: bad_vectors };
    let epoch_before = reg.acquire(t).unwrap();
    let err = svc.publish_delta(t, &bad).unwrap_err();
    assert!(matches!(err, Error::Invalid(_)), "unexpected error class: {err}");
    assert_eq!(reg.quarantines(), 1);
    assert_eq!(entry.quarantined_candidates(), 1);
    assert_eq!(entry.generation(), g0 + 1);
    let epoch_after = reg.acquire(t).unwrap();
    assert!(Arc::ptr_eq(&epoch_before, &epoch_after), "quarantine must not swap the epoch");
    // A quarantined delta is not a publication.
    assert_eq!(entry.deltas_published(), 1);
    assert_eq!(reg.delta_publishes(), 1);
    assert_eq!(draw(&svc, t, 4).unwrap().len(), 4);

    // An indefinite perturbation passes the finite screen but fails the
    // spectrum validator — same quarantine path, same invariants.
    let indefinite = KernelDelta::Perturb {
        side: 1,
        rhos: vec![-100.0],
        vectors: rng.uniform_matrix(4, 1, 0.5, 1.0),
    };
    let err = svc.publish_delta(t, &indefinite).unwrap_err();
    assert!(err.to_string().contains("indefinite"), "unexpected quarantine reason: {err}");
    assert_eq!(reg.quarantines(), 2);
    assert_eq!(entry.generation(), g0 + 1);
    assert_eq!(draw(&svc, t, 3).unwrap().len(), 3);

    // The tenant still absorbs healthy deltas after the quarantines.
    let good2 = KernelDelta::Perturb {
        side: 0,
        rhos: vec![-0.5],
        vectors: rng.uniform_matrix(8, 1, -0.05, 0.05),
    };
    let out = svc.publish_delta(t, &good2).unwrap();
    assert_eq!(out.generation, g0 + 2);
    assert_eq!(draw(&svc, t, 3).unwrap().len(), 3);
    svc.shutdown();
}

/// Three injected primary-path failures against a threshold-2 breaker,
/// served one request at a time: the exact trip/probe/recover schedule
/// is deterministic, every request is still answered (degraded), and
/// the counters balance to the request count.
#[test]
fn injected_failures_trip_breaker_and_fallback_absorbs_them() {
    let reg = Arc::new(KernelRegistry::new(0));
    let t = reg.add_tenant("alpha", &kernel(4, 4, 21)).unwrap();
    let plan = Arc::new(FaultPlan::seeded_from_env(0xBADC0DE).fail_exact(t, 3));
    let cfg = ServiceConfig {
        workers: 1,
        max_batch: 1,
        batch_window_us: 0,
        fallback: FallbackPolicy {
            enabled: true,
            breaker_threshold: 2,
            probe_every: 2,
            regularize_eps: vec![1e-4],
            degrade: vec![],
        },
        ..ServiceConfig::default()
    };
    let svc =
        DppService::start_with_registry_and_faults(Arc::clone(&reg), &cfg, 22, Arc::clone(&plan))
            .unwrap();

    // Schedule with fail_exact budget 3, threshold 2, probe_every 2:
    //   req1 fail (f=1) → fallback        req2 fail (f=2) trips → fallback
    //   req3 open, no probe → fallback    req4 probe, fault 3 fires → fallback
    //   req5 open, no probe → fallback    req6 probe, budget dry → recovers
    //   req7..8 closed → primary
    for i in 0..8 {
        let y = draw(&svc, t, 2).unwrap_or_else(|e| panic!("request {i} must be served: {e}"));
        assert_eq!(y.len(), 2);
        assert!(y.iter().all(|&item| item < 16));
    }

    assert_eq!(plan.fired_exact(t), 3, "seed {}", plan.seed());
    let entry = reg.entry(t).unwrap();
    assert_eq!(entry.breaker_trips(), 1);
    assert_eq!(entry.breaker_recoveries(), 1);
    assert_eq!(entry.breaker_state(), "closed");

    let m = svc.metrics();
    assert_eq!(m.completed.load(Ordering::Relaxed), 8);
    assert_eq!(m.failed.load(Ordering::Relaxed), 0);
    assert_eq!(m.fallback.probes.load(Ordering::Relaxed), 2);
    assert_eq!(m.fallback.regularized.load(Ordering::Relaxed), 5);
    assert_eq!(m.fallback.served(), 5);
    assert_eq!(m.fallback.exhausted.load(Ordering::Relaxed), 0);
    assert_eq!(entry.metrics().fallback_served.load(Ordering::Relaxed), 5);
    svc.shutdown();
}

/// A worker panic fails only the coalesced group it was serving: the
/// other tenant never sees an error, queued work survives the respawn
/// hand-over, and the supervisor replaces the worker (twice).
#[test]
fn worker_panics_are_contained_and_the_pool_heals() {
    let reg = Arc::new(KernelRegistry::new(0));
    let a = reg.add_tenant("alpha", &kernel(4, 4, 31)).unwrap();
    let b = reg.add_tenant("beta", &kernel(3, 3, 32)).unwrap();
    let plan = Arc::new(FaultPlan::seeded_from_env(7).panic_worker(a, 2));
    let cfg = ServiceConfig {
        workers: 2,
        max_batch: 1,
        batch_window_us: 0,
        ..ServiceConfig::default()
    };
    let svc =
        DppService::start_with_registry_and_faults(Arc::clone(&reg), &cfg, 33, Arc::clone(&plan))
            .unwrap();

    let mut panicked = 0u64;
    let mut served_a = 0u64;
    for i in 0..8 {
        match draw(&svc, a, 3) {
            Ok(y) => {
                assert_eq!(y.len(), 3);
                served_a += 1;
            }
            Err(Error::Service(m)) => {
                assert!(m.contains("panicked"), "request {i}: unexpected failure: {m}");
                panicked += 1;
            }
            Err(e) => panic!("request {i}: unexpected error class: {e}"),
        }
        // The sibling tenant must be completely unaffected, including
        // while the panicked worker's queue is mid-hand-over.
        let y = draw(&svc, b, 2).expect("tenant beta must never observe alpha's faults");
        assert_eq!(y.len(), 2);
        assert!(y.iter().all(|&item| item < 9));
    }
    assert_eq!(panicked, 2);
    assert_eq!(served_a, 6);
    assert_eq!(plan.fired_panics(a), 2);

    let m = svc.metrics();
    assert_eq!(m.worker_panics.load(Ordering::Relaxed), 2);
    assert!(
        wait_for(5_000, || m.worker_respawns.load(Ordering::Relaxed) == 2),
        "supervisor must respawn both retired workers, saw {}",
        m.worker_respawns.load(Ordering::Relaxed)
    );

    let ea = reg.entry(a).unwrap();
    let eb = reg.entry(b).unwrap();
    assert_eq!(ea.metrics().completed.load(Ordering::Relaxed), 6);
    assert_eq!(ea.metrics().failed.load(Ordering::Relaxed), 2);
    assert_eq!(eb.metrics().completed.load(Ordering::Relaxed), 8);
    assert_eq!(eb.metrics().failed.load(Ordering::Relaxed), 0);
    assert!(svc.report().contains("worker_panics=2"), "report: {}", svc.report());
    svc.shutdown();
}

/// Injected serve stalls push budgeted requests past their deadline:
/// they fail with a retryable `Deadline` error (never a hang or a
/// silent drop), unbudgeted requests still complete, and the
/// accounting closes exactly.
#[test]
fn slow_serves_exhaust_budgets_into_deadline_errors() {
    let reg = Arc::new(KernelRegistry::new(0));
    let t = reg.add_tenant("alpha", &kernel(4, 4, 41)).unwrap();
    let plan =
        Arc::new(FaultPlan::seeded_from_env(0x51).slow_serve(t, 2, Duration::from_millis(250)));
    let cfg = ServiceConfig {
        workers: 1,
        max_batch: 1,
        batch_window_us: 0,
        ..ServiceConfig::default()
    };
    let svc =
        DppService::start_with_registry_and_faults(Arc::clone(&reg), &cfg, 42, Arc::clone(&plan))
            .unwrap();

    for i in 0..2 {
        let err = svc
            .submit(SampleRequest::for_tenant(t, 2).with_budget(Duration::from_millis(100)))
            .unwrap()
            .wait()
            .unwrap_err();
        assert!(matches!(err, Error::Deadline(_)), "request {i}: expected deadline, got {err}");
        assert!(err.is_retryable());
    }
    for _ in 0..3 {
        assert_eq!(draw(&svc, t, 2).unwrap().len(), 2);
    }

    // Both stalls land on budgeted requests unless the worker pickup
    // itself ate the budget (then the sweep expires the request before
    // the stall fires) — either way the ledger must close.
    assert!(plan.fired_slow(t) <= 2);
    let m = svc.metrics();
    assert_eq!(m.accepted.load(Ordering::Relaxed), 5);
    assert_eq!(m.deadline_exceeded.load(Ordering::Relaxed), 2);
    assert_eq!(m.completed.load(Ordering::Relaxed), 3);
    assert_eq!(m.failed.load(Ordering::Relaxed), 0);
    let entry = reg.entry(t).unwrap();
    assert_eq!(entry.metrics().deadline_exceeded.load(Ordering::Relaxed), 2);
    svc.shutdown();
}

/// Chaos at the wire boundary: injected serve stalls blow wire-carried
/// budgets into retryable `Deadline` envelopes, a client that half-
/// closes with requests in flight still gets every accepted job booked,
/// and the drain completes with the ledger exact.
#[test]
fn wire_slow_serves_and_dropped_connections_keep_the_ledger_exact() {
    let reg = Arc::new(KernelRegistry::new(0));
    let t = reg.add_tenant("alpha", &kernel(4, 4, 71)).unwrap();
    let plan =
        Arc::new(FaultPlan::seeded_from_env(0xD1E).slow_serve(t, 3, Duration::from_millis(150)));
    let cfg = ServiceConfig {
        workers: 2,
        max_batch: 4,
        batch_window_us: 100,
        ..ServiceConfig::default()
    };
    let svc = Arc::new(
        DppService::start_with_registry_and_faults(Arc::clone(&reg), &cfg, 72, Arc::clone(&plan))
            .unwrap(),
    );
    let server =
        NetServer::start(Arc::clone(&svc), "127.0.0.1:0", NetConfig::default()).unwrap();
    let addr = server.local_addr().to_string();

    // Phase 1 — budgeted requests, one at a time so each stall lands on
    // its own group serve: a 150ms stall against a 50ms wire budget must
    // come back as a retryable Deadline envelope (never a hang).
    let mut client = WireClient::connect_timeout(&addr, Duration::from_secs(30)).unwrap();
    for i in 0..3 {
        let err = client
            .sample("alpha", 2 + i % 3, SampleMode::Exact, vec![], vec![], Some(50))
            .unwrap_err();
        assert_eq!(err.kind(), ErrorKind::Deadline, "request {i}: {err}");
        assert!(err.is_retryable());
    }
    // Stall budget consumed (or swept at pickup — either way Deadline):
    // budgeted requests now complete.
    for i in 0..2 {
        let y = client
            .sample("alpha", 2 + i, SampleMode::Exact, vec![], vec![], Some(5_000))
            .unwrap();
        assert_eq!(y.len(), 2 + i);
    }
    assert!(plan.fired_slow(t) <= 3);

    // Phase 2 — a raw client pipelines 4 unbudgeted requests and half-
    // closes without ever reading a byte back: the server must absorb
    // the EOF, serve the admitted work, and book every outcome.
    let mut raw = std::net::TcpStream::connect(&addr).unwrap();
    raw.set_nodelay(true).unwrap();
    for (i, k) in [2usize, 3, 4, 2].iter().enumerate() {
        let frame = WireRequest::Sample {
            id: 100 + i as u64,
            tenant: "alpha".into(),
            k: *k,
            mode: SampleMode::Exact,
            include: vec![],
            exclude: vec![],
            budget_ms: None,
        }
        .to_frame(DEFAULT_MAX_FRAME)
        .unwrap();
        raw.write_all(&frame).unwrap();
    }
    raw.shutdown(std::net::Shutdown::Write).unwrap();

    // Ledger closes exactly: 5 wire requests + 4 orphaned ones, every
    // one booked as completed or deadline-exceeded, nothing failed,
    // nothing dangling.
    let m = svc.metrics();
    assert!(
        wait_for(10_000, || {
            m.accepted.load(Ordering::Relaxed) == 9
                && m.completed.load(Ordering::Relaxed)
                    + m.deadline_exceeded.load(Ordering::Relaxed)
                    == 9
                && svc.in_flight() == 0
        }),
        "wire chaos ledger never closed: accepted={} completed={} deadline={} in_flight={}",
        m.accepted.load(Ordering::Relaxed),
        m.completed.load(Ordering::Relaxed),
        m.deadline_exceeded.load(Ordering::Relaxed),
        svc.in_flight(),
    );
    assert_eq!(m.deadline_exceeded.load(Ordering::Relaxed), 3);
    assert_eq!(m.completed.load(Ordering::Relaxed), 6);
    assert_eq!(m.failed.load(Ordering::Relaxed), 0);
    drop(raw);

    // Drain completes after the chaos: wire shutdown, loop exits.
    client.shutdown_server().unwrap();
    server.join();
    assert!(svc.is_shutdown());
    assert_eq!(svc.in_flight(), 0);
}

/// Full two-tenant chaos: exact failures, a fallback-rung failure, a
/// worker panic, and serve stalls all at once under concurrent
/// clients. Every fault budget fires exactly, every ticket resolves,
/// the per-tenant and global ledgers balance against what the clients
/// observed, and shutdown returns.
#[test]
fn two_tenant_chaos_preserves_accounting_and_shuts_down_clean() {
    let reg = Arc::new(KernelRegistry::with_history(0, 4));
    let a = reg.add_tenant("alpha", &kernel(4, 4, 51)).unwrap();
    let b = reg.add_tenant("beta", &kernel(3, 3, 52)).unwrap();
    let plan = Arc::new(
        FaultPlan::seeded_from_env(0xFEED)
            .fail_exact(a, 4)
            .fail_fallback(a, 1)
            .slow_serve(a, 2, Duration::from_millis(40))
            .panic_worker(b, 1),
    );
    let cfg = ServiceConfig {
        workers: 2,
        max_batch: 4,
        batch_window_us: 100,
        queue_capacity: 4096,
        fallback: FallbackPolicy {
            enabled: true,
            breaker_threshold: 3,
            probe_every: 2,
            regularize_eps: vec![1e-5],
            degrade: vec![SampleMode::LowRank { rank: 16 }],
        },
        ..ServiceConfig::default()
    };
    let svc = Arc::new(
        DppService::start_with_registry_and_faults(Arc::clone(&reg), &cfg, 53, Arc::clone(&plan))
            .unwrap(),
    );

    let ok_a = Arc::new(AtomicU64::new(0));
    let err_a = Arc::new(AtomicU64::new(0));
    let ok_b = Arc::new(AtomicU64::new(0));
    let err_b = Arc::new(AtomicU64::new(0));
    let mut handles = Vec::new();
    for (tenant, n, kmax, ok, err) in [
        (a, 16usize, 4usize, &ok_a, &err_a),
        (a, 16, 4, &ok_a, &err_a),
        (b, 9, 3, &ok_b, &err_b),
        (b, 9, 3, &ok_b, &err_b),
    ] {
        let svc2 = Arc::clone(&svc);
        let ok2 = Arc::clone(ok);
        let err2 = Arc::clone(err);
        handles.push(std::thread::spawn(move || {
            for i in 0..25usize {
                match draw(&svc2, tenant, 1 + i % kmax) {
                    Ok(y) => {
                        assert_eq!(y.len(), 1 + i % kmax);
                        assert!(y.iter().all(|&item| item < n));
                        ok2.fetch_add(1, Ordering::SeqCst);
                    }
                    // The only legal failure in this mix is the
                    // panicked group; no budgets, so never Deadline.
                    Err(Error::Service(m)) => {
                        assert!(m.contains("panicked"), "unexpected service error: {m}");
                        err2.fetch_add(1, Ordering::SeqCst);
                    }
                    Err(e) => panic!("unexpected error class: {e}"),
                }
            }
        }));
    }
    for h in handles {
        h.join().unwrap();
    }

    // Every fault budget fired exactly.
    assert_eq!(plan.fired_exact(a), 4, "seed {}", plan.seed());
    assert_eq!(plan.fired_fallback(a), 1);
    assert_eq!(plan.fired_slow(a), 2);
    assert_eq!(plan.fired_panics(b), 1);

    // Per-tenant ledgers close against client-observed outcomes.
    for (tenant, ok, err) in [(a, &ok_a, &err_a), (b, &ok_b, &err_b)] {
        let entry = reg.entry(tenant).unwrap();
        let tm = entry.metrics();
        let (acc, comp, fail) = (
            tm.accepted.load(Ordering::Relaxed),
            tm.completed.load(Ordering::Relaxed),
            tm.failed.load(Ordering::Relaxed),
        );
        assert_eq!(acc, 50);
        assert_eq!(comp, ok.load(Ordering::SeqCst));
        assert_eq!(fail, err.load(Ordering::SeqCst));
        assert_eq!(acc, comp + fail, "tenant {tenant:?} ledger must close");
        assert_eq!(tm.rejected_invalid.load(Ordering::Relaxed), 0);
        assert_eq!(tm.deadline_exceeded.load(Ordering::Relaxed), 0);
    }
    // All of alpha's exact failures were absorbed by the fallback
    // chain (the injected rung failure just skipped to the next rung);
    // only beta's panicked group failed, and it failed exactly once
    // per job in that group.
    assert_eq!(err_a.load(Ordering::SeqCst), 0);
    let failed_b = err_b.load(Ordering::SeqCst);
    assert!((1..=4).contains(&failed_b), "panic fails one group of ≤ max_batch: {failed_b}");

    let m = svc.metrics();
    let (acc, comp, fail) = (
        m.accepted.load(Ordering::Relaxed),
        m.completed.load(Ordering::Relaxed),
        m.failed.load(Ordering::Relaxed),
    );
    assert_eq!(acc, 100);
    assert_eq!(acc, comp + fail, "global ledger must close");
    assert_eq!(m.worker_panics.load(Ordering::Relaxed), 1);
    assert!(m.fallback.served() >= 4, "{}", m.fallback.summary());
    assert_eq!(m.fallback.exhausted.load(Ordering::Relaxed), 0);
    assert!(
        wait_for(5_000, || m.worker_respawns.load(Ordering::Relaxed) == 1),
        "supervisor must respawn the panicked worker"
    );

    // Shutdown must return promptly even after panics and respawns.
    match Arc::try_unwrap(svc) {
        Ok(s) => s.shutdown(),
        Err(_) => panic!("service still shared after clients joined"),
    }
}
