//! Shared helpers for the integration-test suite. Each test binary pulls
//! this in with `mod common;`, so everything here must be self-contained.
#![allow(dead_code)]

pub mod stats;
