//! The statistical conformance toolkit: brute-force subset laws by
//! enumeration, chi-square goodness-of-fit with tail merging, and
//! binomial marginal checks. All bounds are 4σ against a *fixed* seed
//! (overridable via `KRONDPP_CONFORMANCE_SEED`), so the suite is
//! deterministic: a failure is a real distribution change, not noise.

use krondpp::dpp::{Constraint, Kernel, SampleScratch, SamplerBackend};
use krondpp::linalg::{lu, Matrix};
use krondpp::rng::Rng;
use std::collections::HashMap;

/// Base seed for every conformance test. Pinned in CI via the
/// `KRONDPP_CONFORMANCE_SEED` env var so reruns are bit-identical.
pub fn seed() -> u64 {
    std::env::var("KRONDPP_CONFORMANCE_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(2016)
}

/// A small well-conditioned SPD factor for building test kernels.
pub fn spd(n: usize, seed: u64) -> Matrix {
    let mut rng = Rng::new(seed);
    let mut m = rng.paper_init_kernel(n);
    m.scale_mut(1.5 / n as f64);
    m.add_diag_mut(0.3);
    m
}

/// Brute-force law of the (optionally constrained, optionally fixed-size)
/// DPP by enumerating all `2^N` subsets: `P(Y) ∝ det(L_Y)` over subsets
/// with `A ⊆ Y`, `B ∩ Y = ∅`, and `|Y| = k` when `k` is given. Only
/// usable for the small `N` of the conformance suite.
pub fn subset_law(
    kernel: &Kernel,
    constraint: &Constraint,
    k: Option<usize>,
) -> HashMap<Vec<usize>, f64> {
    let n = kernel.n();
    assert!(n <= 16, "enumeration oracle is O(2^N): N = {n} is too big");
    let dense = kernel.to_dense();
    let amask: u32 = constraint.include().iter().map(|&i| 1u32 << i).sum();
    let bmask: u32 = constraint.exclude().iter().map(|&i| 1u32 << i).sum();
    let mut law = HashMap::new();
    let mut total = 0.0;
    for mask in 0u32..(1u32 << n) {
        if mask & amask != amask || mask & bmask != 0 {
            continue;
        }
        if let Some(k) = k {
            if mask.count_ones() as usize != k {
                continue;
            }
        }
        let subset: Vec<usize> = (0..n).filter(|&i| mask >> i & 1 == 1).collect();
        let w = if subset.is_empty() {
            1.0
        } else {
            lu::det(&dense.principal_submatrix(&subset)).unwrap_or(0.0).max(0.0)
        };
        total += w;
        law.insert(subset, w);
    }
    assert!(total > 0.0, "constraint admits no subset with positive mass");
    for w in law.values_mut() {
        *w /= total;
    }
    law
}

/// Collect `count` draws from a backend (one shared scratch, like the
/// service workers).
pub fn draw_many<B: SamplerBackend>(
    backend: &B,
    k: Option<usize>,
    count: usize,
    rng: &mut Rng,
) -> Vec<Vec<usize>> {
    let mut scratch = SampleScratch::new();
    let mut out = Vec::new();
    let mut draws = Vec::with_capacity(count);
    for _ in 0..count {
        backend.draw_into(k, rng, &mut scratch, &mut out).expect("draw failed");
        draws.push(out.clone());
    }
    draws
}

/// Chi-square goodness-of-fit of `draws` against `law`. Cells whose
/// expected count falls below 5 are merged into one tail cell (standard
/// practice — the χ² normal approximation needs fat cells); the statistic
/// is then bounded by `dof + 4·sqrt(2·dof)`, a 4σ normal bound on the
/// χ²_dof distribution. Draws outside the law's support fail outright.
pub fn chi_square_conformance(
    label: &str,
    draws: &[Vec<usize>],
    law: &HashMap<Vec<usize>, f64>,
) {
    let total = draws.len() as f64;
    let mut counts: HashMap<&[usize], f64> = HashMap::new();
    for d in draws {
        *counts.entry(d.as_slice()).or_insert(0.0) += 1.0;
    }
    for (subset, c) in &counts {
        let p = law.get(*subset).copied().unwrap_or(0.0);
        assert!(
            p > 1e-12,
            "{label}: drew {subset:?} {c} times but the law gives it probability {p:e}"
        );
    }
    let mut stat = 0.0;
    let mut cells = 0.0;
    let mut tail_exp = 0.0;
    let mut tail_obs = 0.0;
    for (subset, &p) in law {
        let expected = p * total;
        let observed = counts.get(subset.as_slice()).copied().unwrap_or(0.0);
        if expected < 5.0 {
            tail_exp += expected;
            tail_obs += observed;
        } else {
            stat += (observed - expected).powi(2) / expected;
            cells += 1.0;
        }
    }
    if tail_exp > 0.0 {
        stat += (tail_obs - tail_exp).powi(2) / tail_exp;
        cells += 1.0;
    }
    let dof = (cells - 1.0).max(1.0);
    let bound = dof + 4.0 * (2.0 * dof).sqrt();
    assert!(
        stat <= bound,
        "{label}: chi-square {stat:.2} exceeds the 4σ bound {bound:.2} \
         (dof {dof}, {} draws over {} cells)",
        draws.len(),
        law.len()
    );
}

/// Empirical inclusion frequencies `#{Y ∋ i} / draws` over a ground set
/// of size `n`.
pub fn empirical_marginals(draws: &[Vec<usize>], n: usize) -> Vec<f64> {
    let mut freq = vec![0.0; n];
    for d in draws {
        for &i in d {
            freq[i] += 1.0;
        }
    }
    let total = draws.len().max(1) as f64;
    freq.iter_mut().for_each(|f| *f /= total);
    freq
}

/// Per-item binomial check: every empirical inclusion frequency must sit
/// within `4σ` (plus a small absolute floor for near-degenerate
/// probabilities) of its exact value.
pub fn check_marginals(label: &str, empirical: &[f64], truth: &[f64], draws: usize) {
    assert_eq!(empirical.len(), truth.len(), "{label}: length mismatch");
    let total = draws as f64;
    for (i, (&e, &t)) in empirical.iter().zip(truth).enumerate() {
        let se = (t * (1.0 - t) / total).max(0.0).sqrt();
        let tol = 4.0 * se + 0.004;
        assert!(
            (e - t).abs() <= tol,
            "{label}: item {i} empirical marginal {e:.4} vs exact {t:.4} \
             (tol {tol:.4} over {draws} draws)"
        );
    }
}
