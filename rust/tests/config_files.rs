//! The checked-in experiment configs under `configs/` must stay parseable
//! by the config system (they are the documented entry points for the
//! paper-scale runs).

use krondpp::config::{LearnConfig, ServiceConfig};
use std::path::Path;

fn configs_dir() -> std::path::PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR")).join("configs")
}

#[test]
fn learn_configs_parse() {
    for name in ["fig1a.json", "table2_paper.json", "stochastic_large.json"] {
        let path = configs_dir().join(name);
        let cfg = LearnConfig::load(&path)
            .unwrap_or_else(|e| panic!("{name} failed to parse: {e}"));
        assert!(cfg.n() > 0, "{name}: empty ground set");
        assert!(cfg.step_size > 0.0);
    }
}

#[test]
fn paper_scale_dimensions_recorded() {
    let cfg = LearnConfig::load(&configs_dir().join("table2_paper.json")).unwrap();
    assert_eq!((cfg.n1, cfg.n2), (100, 100), "Table 2 is defined at N1=N2=100");
    let cfg = LearnConfig::load(&configs_dir().join("stochastic_large.json")).unwrap();
    assert_eq!(cfg.n(), 22_500, "Fig 1c scale");
    assert!(cfg.minibatch >= 1);
}

#[test]
fn service_config_parses() {
    let cfg = ServiceConfig::load(&configs_dir().join("service.json")).unwrap();
    assert_eq!(cfg.max_batch, 32);
    assert!(cfg.workers >= 1);
    assert_eq!(cfg.queue_capacity, 1024);
    // Multi-tenant section: bounded residency + declared tenants.
    assert_eq!(cfg.max_resident_epochs, 8);
    assert_eq!(cfg.tenants.len(), 2);
    assert_eq!(cfg.tenants[0].name, "market-eu");
    assert_eq!(cfg.tenants[1].name, "market-us");
    assert!(cfg.tenants.iter().all(|t| t.n1 > 0 && t.n2 > 0));
    // Robustness section: rollback depth, default deadline budget, and
    // the breaker + degraded-mode fallback chain.
    assert_eq!(cfg.epoch_history, 4);
    assert_eq!(cfg.default_budget_ms, 250);
    assert!(cfg.fallback.enabled);
    assert_eq!(cfg.fallback.breaker_threshold, 3);
    assert_eq!(cfg.fallback.probe_every, 4);
    assert_eq!(cfg.fallback.regularize_eps, vec![1e-6, 1e-3]);
    assert_eq!(
        cfg.fallback.degrade,
        vec![
            krondpp::dpp::SampleMode::LowRank { rank: 32 },
            krondpp::dpp::SampleMode::Mcmc { steps: 2000 },
        ]
    );
}
