//! Counting-allocator proof that steady-state KRK-Picard updates perform
//! **zero heap allocations**, on both the Θ-consuming half-update API and
//! the full Θ-free compressed step.
//!
//! Region A — the Prop. 3.1 update given a precomputed Θ: Θ-contraction
//! (`A₁`/`A₂`), the `L·A·L` sandwich, the eigen-space `L·B·L` term (two
//! sub-kernel eigendecompositions), and the PD-safeguarded step —
//! everything `update_l1_from_theta` / `update_l2_from_theta` touch.
//!
//! Region B — a full `Learner::step` on the Θ-free path: the compressed-
//! statistics fingerprint check, two fused engine sweeps (gather each
//! `L_Y`, Cholesky factor, in-place inverse, `O(κ²)` contraction
//! accumulation into stripe partials, logdet fusion), the fused-objective
//! bookkeeping, and both half-updates. No `N×N` Θ exists on this path at
//! all.
//!
//! Region C — warmed conditioned draws: a fixed `Constraint` is compiled
//! once into a `ConditionedSampler` (Schur assembly + eigendecomposition —
//! the warmup), then repeated `sample_into` draws (phase 1 over the
//! conditional spectrum, incremental phase 2, rest-index remap + include
//! merge) run against a caller-held scratch and result buffer. A
//! worst-case `sample_k_into(max_k)` warmup pins every buffer at its
//! maximum size, so the measured draws cannot allocate no matter how many
//! eigenvectors phase 1 selects.
//!
//! Region D — warmed sampler-zoo serving paths: a greedy MAP slate build
//! (`map_slate_into` against a caller-held `MapScratch` — the per-worker
//! setup of the service's MAP mode) and low-rank spectral-projection
//! draws (`LowRankBackend` built once from a cached eigendecomposition,
//! like a registry epoch) both run allocation-free once warmed.
//!
//! Region E — the SIMD-dispatched linalg substrate: packed GEMM calls at
//! a register-tile volume against a caller-held `GemmScratch` (pack
//! buffers sized to the selected kernel's MR/NR on warmup, micro-tiles
//! staged on the stack, the dispatch table a `OnceLock` of fn pointers),
//! and the factored Kron2 marginal-diagonal sweep (vectorized squared-
//! eigenvector fills, `λ/(1+λ)` weight grid, two GEMMs) against a warmed
//! `MarginalScratch`.
//!
//! Region F — the steady-state delta-publish refresh: a cached factor
//! eigendecomposition updated under a rank-r perturbation via
//! `eigen_update::refresh_into` (eigen-coordinate projection, deflation,
//! secular solve, eigenvector GEMM) against a caller-held
//! `EigenUpdateScratch` — the registry's `publish_delta` hot loop.
//! (The surrounding epoch install allocates by design: a fresh
//! `Arc<SamplerEpoch>` plus the recombined Kron eigenvalue product —
//! that's the swap, not the refresh.)
//!
//! Buffers are grown on the warm-up iterations; after that no region may
//! hit the allocator.
//!
//! Scope note: the claim is asserted with `KRONDPP_THREADS=1` (set before
//! any thread-count lookup) and at sub-kernel sizes below the
//! parallel-dispatch thresholds (the common KronDPP regime,
//! N₁, N₂ ≲ 100) — worker-thread spawns allocate by nature. This file
//! holds exactly one test so no concurrent test can perturb the global
//! counter.

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};

use krondpp::dpp::likelihood::theta_dense;
use krondpp::dpp::{
    map_slate_into, ConditionedSampler, Constraint, Kernel, LowRankBackend, MapScratch,
    SampleScratch, Sampler, SamplerBackend,
};
use krondpp::learn::krk::KrkPicard;
use krondpp::learn::traits::{Learner, TrainingSet};
use krondpp::linalg::Matrix;
use krondpp::rng::Rng;

struct CountingAlloc;

static ALLOCS: AtomicUsize = AtomicUsize::new(0);
static ENABLED: AtomicBool = AtomicBool::new(false);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        if ENABLED.load(Ordering::Relaxed) {
            ALLOCS.fetch_add(1, Ordering::Relaxed);
        }
        System.alloc(layout)
    }
    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        if ENABLED.load(Ordering::Relaxed) {
            ALLOCS.fetch_add(1, Ordering::Relaxed);
        }
        System.alloc_zeroed(layout)
    }
    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        if ENABLED.load(Ordering::Relaxed) {
            ALLOCS.fetch_add(1, Ordering::Relaxed);
        }
        System.realloc(ptr, layout, new_size)
    }
    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }
}

#[global_allocator]
static GLOBAL: CountingAlloc = CountingAlloc;

fn sub_kernel(n: usize, rng: &mut Rng) -> Matrix {
    let mut l = rng.paper_init_kernel(n);
    l.scale_mut(1.5 / n as f64);
    l.add_diag_mut(0.3);
    l
}

fn measure(label: &str, mut f: impl FnMut()) {
    ALLOCS.store(0, Ordering::SeqCst);
    ENABLED.store(true, Ordering::SeqCst);
    f();
    ENABLED.store(false, Ordering::SeqCst);
    let count = ALLOCS.load(Ordering::SeqCst);
    assert_eq!(count, 0, "steady-state {label} hit the allocator {count} times");
}

#[test]
fn krk_update_and_step_paths_are_allocation_free_in_steady_state() {
    // Pin the thread count before anything caches it: single-worker mode
    // makes every parallel dispatch take its inline path.
    std::env::set_var("KRONDPP_THREADS", "1");

    let (n1, n2) = (8usize, 8usize);
    let mut rng = Rng::new(42);
    let truth = Kernel::Kron2(sub_kernel(n1, &mut rng), sub_kernel(n2, &mut rng));
    let sampler = Sampler::new(&truth).unwrap();
    let subsets: Vec<Vec<usize>> = (0..40).map(|_| sampler.sample(&mut rng)).collect();
    let data = TrainingSet::new(n1 * n2, subsets).unwrap();

    // step_size > 1 exercises the PD-safeguard (candidate build, Cholesky
    // check, possible unit-step rebuild) inside the measured regions.
    let mut learner =
        KrkPicard::new(sub_kernel(n1, &mut rng), sub_kernel(n2, &mut rng), 1.3).unwrap();
    let theta = theta_dense(&learner.kernel(), &data.subsets).unwrap();

    // Region A warm-up: grows every learner-held buffer (contractions,
    // sandwich temps, eigen scratches, candidate/rollback, GEMM packs, the
    // thread-local transpose staging) to its steady-state size.
    for _ in 0..3 {
        learner.update_l1_from_theta(&theta).unwrap();
        learner.update_l2_from_theta(&theta).unwrap();
    }
    measure("Θ-based half-update path", || {
        for _ in 0..5 {
            learner.update_l1_from_theta(&theta).unwrap();
            learner.update_l2_from_theta(&theta).unwrap();
        }
    });

    // Region B warm-up: builds the compressed-statistics arena (sorted
    // dedup + index splits) and grows the engine's stripe partials and
    // gather/factor/inverse buffers.
    for _ in 0..3 {
        learner.step(&data).unwrap();
    }
    measure("Θ-free compressed step path", || {
        for _ in 0..5 {
            learner.step(&data).unwrap();
        }
    });

    // The updates above must still be doing real work: the learner's
    // kernel should have moved and stayed PD, and the fused objective
    // must be populated.
    let (l1, l2) = learner.subkernels();
    assert!(krondpp::linalg::cholesky::is_pd(l1));
    assert!(krondpp::linalg::cholesky::is_pd(l2));
    assert!(learner.pre_step_objective().unwrap().is_finite());

    // Region C warm-up: the conditioning setup itself (bordered-block
    // gathers, L_A Cholesky, rank-|A| correction, Lᶜ eigendecomposition)
    // allocates once; a worst-case full-size k-DPP draw then pins the
    // phase-2 basis, weights, contraction and result buffers at their
    // maxima, and a few unconstrained draws warm the phase-1 path.
    let constraint = Constraint::new(vec![3, 20], vec![10, 17, 41]).unwrap();
    let cond = ConditionedSampler::new(&truth, constraint).unwrap();
    let mut draw_rng = Rng::new(7);
    let mut draw_scratch = SampleScratch::new();
    let mut out = Vec::new();
    cond.sample_k_into(cond.max_k(), &mut draw_rng, &mut draw_scratch, &mut out);
    assert_eq!(out.len(), cond.max_k());
    for _ in 0..10 {
        cond.sample_into(&mut draw_rng, &mut draw_scratch, &mut out);
    }
    measure("conditioned draw path", || {
        for _ in 0..50 {
            cond.sample_into(&mut draw_rng, &mut draw_scratch, &mut out);
        }
    });
    // The measured draws must still be real conditioned samples.
    assert!(out.contains(&3) && out.contains(&20));
    assert!(!out.contains(&10) && !out.contains(&17) && !out.contains(&41));
    assert!(out.iter().all(|&i| i < n1 * n2));

    // Region D warm-up: greedy MAP grows its per-candidate solve rows and
    // gain table once for the largest slate it serves (the service
    // worker's per-worker MapScratch discipline); repeated slates then
    // reuse every buffer, `sort_unstable` included.
    let map_constraint = Constraint::new(vec![1, 9], vec![5, 33]).unwrap();
    let mut map_scratch = MapScratch::new();
    let mut slate = Vec::new();
    for _ in 0..2 {
        map_slate_into(&truth, Some(12), &map_constraint, &mut map_scratch, &mut slate)
            .unwrap();
    }
    measure("greedy MAP slate path", || {
        for _ in 0..10 {
            map_slate_into(&truth, Some(12), &map_constraint, &mut map_scratch, &mut slate)
                .unwrap();
        }
    });
    assert_eq!(slate.len(), 12);
    assert!(slate.contains(&1) && slate.contains(&9));
    assert!(!slate.contains(&5) && !slate.contains(&33));

    // Low-rank projection built once from the cached spectrum (an O(N·r)
    // gather, exactly what the serving path does per coalesced group); a
    // worst-case rank-sized k-DPP draw pins the engine buffers, then the
    // measured size-varying draws must stay off the allocator.
    let lowrank = LowRankBackend::from_eigen(sampler.eigen(), 16, Constraint::none()).unwrap();
    let mut lr_out = Vec::new();
    lowrank
        .draw_into(Some(16), &mut draw_rng, &mut draw_scratch, &mut lr_out)
        .unwrap();
    for _ in 0..10 {
        lowrank.draw_into(None, &mut draw_rng, &mut draw_scratch, &mut lr_out).unwrap();
    }
    measure("low-rank projection draw path", || {
        for _ in 0..50 {
            lowrank
                .draw_into(None, &mut draw_rng, &mut draw_scratch, &mut lr_out)
                .unwrap();
        }
    });
    assert!(lr_out.len() <= 16);
    assert!(lr_out.iter().all(|&i| i < n1 * n2));

    // Region E warm-up: resolve the SIMD dispatch (the env-var read at
    // first lookup is the only allocation it ever makes), grow the pack
    // buffers to the selected kernel's MR/NR geometry at this problem
    // size, and grow the marginal scratch. 96³ clears the packed-path
    // volume threshold, so the measured calls run the register-tile
    // micro-kernel — the micro-tile itself is staged on the stack.
    use krondpp::dpp::MarginalScratch;
    use krondpp::linalg::matmul::GemmScratch;
    use krondpp::linalg::simd;
    let kern = simd::active();
    assert!(!kern.name().is_empty());
    let ga = sub_kernel(96, &mut rng);
    let gb = sub_kernel(96, &mut rng);
    let mut gc = Matrix::zeros(96, 96);
    let mut gemm_scratch = GemmScratch::new();
    gemm_into_warm(&mut gc, &ga, &gb, &mut gemm_scratch);
    let marg_kernel = Kernel::Kron2(sub_kernel(24, &mut rng), sub_kernel(32, &mut rng));
    let marg_eig = marg_kernel.eigen().unwrap();
    let mut marg_scratch = MarginalScratch::new();
    let mut diag = Vec::new();
    for _ in 0..2 {
        marg_eig.inclusion_probabilities_into(&mut diag, &mut marg_scratch);
    }
    measure("dispatched GEMM + marginal-diagonal path", || {
        for _ in 0..5 {
            gemm_into_warm(&mut gc, &ga, &gb, &mut gemm_scratch);
            marg_eig.inclusion_probabilities_into(&mut diag, &mut marg_scratch);
        }
    });
    assert_eq!(diag.len(), 24 * 32);
    assert!(diag.iter().all(|&p| (0.0..=1.0 + 1e-12).contains(&p)));
    assert!(gc.as_slice().iter().all(|v| v.is_finite()));

    // Region F warm-up: one secular refresh grows the update scratch
    // (eigen-coordinate projection, deflation mask, secular operands,
    // rotated eigenvector panel) to the factor's size; repeated rank-2
    // refreshes — the registry's per-delta hot path — then stay off the
    // allocator entirely.
    use krondpp::linalg::eigen_update::{
        refresh_into, EigenUpdateScratch, UpdateOptions, UpdateOutcome,
    };
    use krondpp::linalg::SymEigen;
    let fl = sub_kernel(64, &mut rng);
    let feig = SymEigen::new(&fl).unwrap();
    let rhos = [0.4f64, -0.2];
    let vs = rng.uniform_matrix(64, 2, -0.05, 0.05);
    let opts = UpdateOptions::default();
    let mut upd_scratch = EigenUpdateScratch::new();
    for _ in 0..2 {
        let out = refresh_into(&feig.values, &feig.vectors, &rhos, &vs, &opts, &mut upd_scratch);
        assert!(matches!(out, UpdateOutcome::Applied { .. }));
    }
    measure("rank-r secular eigen refresh path", || {
        for _ in 0..5 {
            let out =
                refresh_into(&feig.values, &feig.vectors, &rhos, &vs, &opts, &mut upd_scratch);
            assert!(matches!(out, UpdateOutcome::Applied { .. }));
        }
    });
    // The measured refreshes must still produce a real spectrum: ascending
    // finite eigenvalues matching the perturbed trace.
    assert_eq!(upd_scratch.values.len(), 64);
    assert!(upd_scratch.values.windows(2).all(|w| w[0] <= w[1]));
    let trace: f64 = (0..64).map(|i| fl.get(i, i)).sum();
    let vtv: f64 = (0..2)
        .map(|k| rhos[k] * (0..64).map(|i| vs.get(i, k) * vs.get(i, k)).sum::<f64>())
        .sum();
    let refreshed: f64 = upd_scratch.values.iter().sum();
    assert!((refreshed - (trace + vtv)).abs() < 1e-8 * trace.abs().max(1.0));
}

/// One packed-path GEMM against caller-held scratch (helper so warmup and
/// the measured region run the identical call).
fn gemm_into_warm(
    c: &mut krondpp::linalg::Matrix,
    a: &krondpp::linalg::Matrix,
    b: &krondpp::linalg::Matrix,
    s: &mut krondpp::linalg::matmul::GemmScratch,
) {
    krondpp::linalg::matmul::gemm_into(c.view_mut(), 1.0, a.view(), b.view(), false, s);
}
