"""AOT pipeline: lowering produces parseable HLO text + a valid manifest."""

import json
import os
import subprocess
import sys

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.dirname(os.path.abspath(__file__))))
PYDIR = os.path.join(REPO, "python")


@pytest.fixture(scope="module")
def artifact_dir(tmp_path_factory):
    out = tmp_path_factory.mktemp("artifacts")
    subprocess.run(
        [sys.executable, "-m", "compile.aot", "--out", str(out), "--sizes", "4"],
        cwd=PYDIR,
        check=True,
    )
    return out


def test_manifest_valid(artifact_dir):
    with open(artifact_dir / "manifest.json") as f:
        manifest = json.load(f)
    assert manifest["dtype"] == "f64"
    names = {a["name"] for a in manifest["artifacts"]}
    assert "krk_contractions_4x4" in names
    assert "krk_l1_term_4x4" in names
    assert "kron_inv_action_4x4" in names
    assert any(n.startswith("gram_") for n in names)
    assert any(n.startswith("picard_ldl_") for n in names)
    for art in manifest["artifacts"]:
        assert (artifact_dir / art["file"]).exists()
        assert art["inputs"] and art["outputs"]


def test_hlo_text_shape_signature(artifact_dir):
    text = (artifact_dir / "krk_contractions_4x4.hlo.txt").read_text()
    assert text.startswith("HloModule")
    # f64 I/O with the declared shapes: Θ is 16x16, outputs 4x4.
    assert "f64[16,16]" in text
    assert "f64[4,4]" in text
    # no LAPACK custom-calls may leak into artifacts (runtime can't run them)
    assert "custom-call" not in text, "artifact contains an unexecutable custom-call"


def test_all_artifacts_free_of_custom_calls(artifact_dir):
    for fname in os.listdir(artifact_dir):
        if fname.endswith(".hlo.txt"):
            text = (artifact_dir / fname).read_text()
            assert "custom-call" not in text, f"{fname} contains custom-call"


def test_outputs_are_tuples(artifact_dir):
    # return_tuple=True: entry computation root must be a tuple for the
    # Rust side's to_tuple() unwrap.
    text = (artifact_dir / "picard_ldl_64.hlo.txt").read_text()
    first_line = text.splitlines()[0]
    assert "->" in first_line and "(" in first_line.split("->")[1]
