"""Layer-1 correctness: every Pallas kernel vs its pure-jnp oracle.

Hypothesis sweeps shapes and dtypes; fixed-seed numpy supplies the data.
"""

import jax
import numpy as np
import pytest

jax.config.update("jax_enable_x64", True)

from hypothesis import given, settings
from hypothesis import strategies as st

from compile.kernels import ref
from compile.kernels.block_trace import block_trace
from compile.kernels.gram import gram
from compile.kernels.weighted_block_sum import weighted_block_sum

DTYPES = [np.float32, np.float64]


def tol(dtype):
    return dict(rtol=2e-5, atol=2e-5) if dtype == np.float32 else dict(rtol=1e-11, atol=1e-11)


def rand(rng, *shape, dtype):
    return rng.standard_normal(shape).astype(dtype)


@settings(max_examples=25, deadline=None)
@given(
    n1=st.integers(min_value=1, max_value=6),
    n2=st.integers(min_value=1, max_value=6),
    dtype_ix=st.integers(min_value=0, max_value=1),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_block_trace_matches_ref(n1, n2, dtype_ix, seed):
    dtype = DTYPES[dtype_ix]
    rng = np.random.default_rng(seed)
    theta = rand(rng, n1 * n2, n1 * n2, dtype=dtype)
    l2 = rand(rng, n2, n2, dtype=dtype)
    got = block_trace(theta, l2, n1=n1, n2=n2)
    want = ref.block_trace_ref(theta, l2, n1, n2)
    np.testing.assert_allclose(got, want, **tol(dtype))


@settings(max_examples=25, deadline=None)
@given(
    n1=st.integers(min_value=1, max_value=6),
    n2=st.integers(min_value=1, max_value=6),
    dtype_ix=st.integers(min_value=0, max_value=1),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_weighted_block_sum_matches_ref(n1, n2, dtype_ix, seed):
    dtype = DTYPES[dtype_ix]
    rng = np.random.default_rng(seed)
    theta = rand(rng, n1 * n2, n1 * n2, dtype=dtype)
    w = rand(rng, n1, n1, dtype=dtype)
    got = weighted_block_sum(theta, w, n1=n1, n2=n2)
    want = ref.weighted_block_sum_ref(theta, w, n1, n2)
    np.testing.assert_allclose(got, want, **tol(dtype))


@settings(max_examples=20, deadline=None)
@given(
    n=st.integers(min_value=1, max_value=96),
    d=st.integers(min_value=1, max_value=48),
    dtype_ix=st.integers(min_value=0, max_value=1),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_gram_matches_ref(n, d, dtype_ix, seed):
    dtype = DTYPES[dtype_ix]
    rng = np.random.default_rng(seed)
    x = rand(rng, n, d, dtype=dtype)
    got = gram(x)
    want = ref.gram_ref(x)
    np.testing.assert_allclose(got, want, **tol(dtype))


@pytest.mark.parametrize("bn,bd", [(128, 128), (32, 16), (7, 3)])
def test_gram_block_size_invariance(bn, bd):
    rng = np.random.default_rng(0)
    x = rand(rng, 70, 21, dtype=np.float64)
    got = gram(x, bn=bn, bd=bd)
    np.testing.assert_allclose(got, ref.gram_ref(x), rtol=1e-11, atol=1e-11)


def test_block_trace_on_kron_structured_theta():
    # If Θ = W ⊗ V then A1[k,l] = W[k,l]·Tr(V·L2).
    rng = np.random.default_rng(1)
    n1, n2 = 4, 5
    w = rand(rng, n1, n1, dtype=np.float64)
    v = rand(rng, n2, n2, dtype=np.float64)
    theta = np.kron(w, v)
    l2 = rand(rng, n2, n2, dtype=np.float64)
    got = np.asarray(block_trace(theta, l2, n1=n1, n2=n2))
    want = w * np.trace(v @ l2)
    np.testing.assert_allclose(got, want, rtol=1e-10, atol=1e-10)


def test_weighted_block_sum_identity_weights():
    # W = I picks the partial trace Tr2(Θ).
    rng = np.random.default_rng(2)
    n1, n2 = 3, 4
    theta = rand(rng, n1 * n2, n1 * n2, dtype=np.float64)
    got = np.asarray(weighted_block_sum(theta, np.eye(n1), n1=n1, n2=n2))
    want = sum(
        theta[i * n2 : (i + 1) * n2, i * n2 : (i + 1) * n2] for i in range(n1)
    )
    np.testing.assert_allclose(got, want, rtol=1e-12, atol=1e-12)
