"""Layer-2 correctness: model graphs vs direct jnp compositions."""

import jax
import numpy as np

jax.config.update("jax_enable_x64", True)

from compile import model
from compile.kernels import ref


def rand(rng, *shape):
    return rng.standard_normal(shape)


def test_krk_l1_term_matches_composition():
    rng = np.random.default_rng(0)
    n1, n2 = 3, 4
    theta = rand(rng, n1 * n2, n1 * n2)
    l1 = rand(rng, n1, n1)
    l2 = rand(rng, n2, n2)
    (got,) = model.krk_l1_term(theta, l1, l2, n1=n1, n2=n2)
    a1 = ref.block_trace_ref(theta, l2, n1, n2)
    want = ref.sandwich_ref(l1, a1)
    np.testing.assert_allclose(got, want, rtol=1e-11, atol=1e-11)


def test_krk_l2_term_matches_composition():
    rng = np.random.default_rng(1)
    n1, n2 = 4, 3
    theta = rand(rng, n1 * n2, n1 * n2)
    l1 = rand(rng, n1, n1)
    l2 = rand(rng, n2, n2)
    (got,) = model.krk_l2_term(theta, l1, l2, n1=n1, n2=n2)
    a2 = ref.weighted_block_sum_ref(theta, l1, n1, n2)
    want = ref.sandwich_ref(l2, a2)
    np.testing.assert_allclose(got, want, rtol=1e-11, atol=1e-11)


def test_krk_contractions_pair():
    rng = np.random.default_rng(2)
    n1, n2 = 3, 3
    theta = rand(rng, 9, 9)
    l1 = rand(rng, 3, 3)
    l2 = rand(rng, 3, 3)
    a1, a2 = model.krk_contractions(theta, l1, l2, n1=n1, n2=n2)
    np.testing.assert_allclose(a1, ref.block_trace_ref(theta, l2, 3, 3), rtol=1e-11)
    np.testing.assert_allclose(
        a2, ref.weighted_block_sum_ref(theta, l1, 3, 3), rtol=1e-11
    )


def test_picard_ldl():
    rng = np.random.default_rng(3)
    l = rand(rng, 6, 6)
    delta = rand(rng, 6, 6)
    (got,) = model.picard_ldl(l, delta)
    np.testing.assert_allclose(got, ref.picard_ldl_ref(l, delta), rtol=1e-11)


def test_inverse_action_matches_dense_solve():
    rng = np.random.default_rng(4)
    n1, n2 = 3, 4
    # PD sub-kernels via Gram.
    x1 = rand(rng, n1, n1)
    x2 = rand(rng, n2, n2)
    l1 = x1.T @ x1 + 0.3 * np.eye(n1)
    l2 = x2.T @ x2 + 0.3 * np.eye(n2)
    d1, p1 = np.linalg.eigh(l1)
    d2, p2 = np.linalg.eigh(l2)
    rhs = rand(rng, n1 * n2)
    (got,) = model.l_plus_i_inverse_action(p1, p2, d1, d2, rhs, n1=n1, n2=n2)
    dense = np.kron(l1, l2) + np.eye(n1 * n2)
    want = np.linalg.solve(dense, rhs)
    np.testing.assert_allclose(got, want, rtol=1e-9, atol=1e-9)
