"""AOT lowering: JAX/Pallas graphs → HLO text artifacts + manifest.

Run once at build time (`make artifacts`); Python never runs on the
request path. The interchange format is HLO *text*, not serialized
HloModuleProto: jax ≥ 0.5 emits protos with 64-bit instruction ids that
the pinned xla_extension 0.5.1 rejects (`proto.id() <= INT_MAX`), while
the text parser reassigns ids and round-trips cleanly (see
/opt/xla-example/README.md).

The manifest (artifacts/manifest.json) lists every artifact with its
input/output shapes and dtype so the Rust runtime can validate call sites
at load time.

Usage: python -m compile.aot [--out DIR] [--sizes 16,32] [--big]
"""

import argparse
import json
import os

import jax
import jax.numpy as jnp

jax.config.update("jax_enable_x64", True)

from jax._src.lib import xla_client as xc

from . import model

DTYPE = jnp.float64
DTYPE_NAME = "f64"


def to_hlo_text(lowered) -> str:
    """StableHLO → XlaComputation → HLO text (ids reassigned by parser)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def spec(*shape):
    return jax.ShapeDtypeStruct(shape, DTYPE)


def build_artifacts(sizes, gram_shapes, picard_sizes):
    """Yield (name, lowered) for every artifact variant."""
    for n1, n2 in sizes:
        n = n1 * n2
        theta = spec(n, n)
        l1 = spec(n1, n1)
        l2 = spec(n2, n2)

        def contractions(theta, l1, l2, n1=n1, n2=n2):
            return model.krk_contractions(theta, l1, l2, n1=n1, n2=n2)

        yield (
            f"krk_contractions_{n1}x{n2}",
            jax.jit(contractions).lower(theta, l1, l2),
        )

        def l1_term(theta, l1, l2, n1=n1, n2=n2):
            return model.krk_l1_term(theta, l1, l2, n1=n1, n2=n2)

        yield (f"krk_l1_term_{n1}x{n2}", jax.jit(l1_term).lower(theta, l1, l2))

        def l2_term(theta, l1, l2, n1=n1, n2=n2):
            return model.krk_l2_term(theta, l1, l2, n1=n1, n2=n2)

        yield (f"krk_l2_term_{n1}x{n2}", jax.jit(l2_term).lower(theta, l1, l2))

        def inv_action(p1, p2, d1, d2, rhs, n1=n1, n2=n2):
            return model.l_plus_i_inverse_action(p1, p2, d1, d2, rhs, n1=n1, n2=n2)

        yield (
            f"kron_inv_action_{n1}x{n2}",
            jax.jit(inv_action).lower(
                spec(n1, n1), spec(n2, n2), spec(n1), spec(n2), spec(n)
            ),
        )

    for n, d in gram_shapes:
        yield (f"gram_{n}x{d}", jax.jit(model.gram_kernel_fn).lower(spec(n, d)))

    for n in picard_sizes:
        yield (f"picard_ldl_{n}", jax.jit(model.picard_ldl).lower(spec(n, n), spec(n, n)))


def shapes_of(lowered):
    args, kwargs = lowered.in_avals
    assert not kwargs, "artifacts must be positional-only"
    return [list(a.shape) for a in args]


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts", help="output directory")
    ap.add_argument(
        "--sizes",
        default="8,16,32",
        help="comma-separated square sub-kernel sizes (n1=n2) to lower",
    )
    ap.add_argument(
        "--big",
        action="store_true",
        help="also lower the 50x50 (N=2500) variants used by the figure harness",
    )
    args = ap.parse_args()

    os.makedirs(args.out, exist_ok=True)
    sizes = [(int(s), int(s)) for s in args.sizes.split(",") if s]
    if args.big and (50, 50) not in sizes:
        sizes.append((50, 50))
    gram_shapes = [(256, 64), (512, 128)]
    picard_sizes = [64, 256]

    manifest = {"dtype": DTYPE_NAME, "artifacts": []}
    for name, lowered in build_artifacts(sizes, gram_shapes, picard_sizes):
        text = to_hlo_text(lowered)
        fname = f"{name}.hlo.txt"
        with open(os.path.join(args.out, fname), "w") as f:
            f.write(text)
        in_shapes = shapes_of(lowered)
        out_shapes = [
            list(o.shape) for o in jax.tree_util.tree_leaves(lowered.out_info)
        ]
        manifest["artifacts"].append(
            {
                "name": name,
                "file": fname,
                "inputs": in_shapes,
                "outputs": out_shapes,
                "dtype": DTYPE_NAME,
            }
        )
        print(f"lowered {name}: {len(text)} chars, in={in_shapes} out={out_shapes}")

    with open(os.path.join(args.out, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"wrote manifest with {len(manifest['artifacts'])} artifacts to {args.out}")


if __name__ == "__main__":
    main()
