"""Pallas kernel: the A2 weighted block-sum contraction (App. B.2).

``A2[p,q] = Σ_{i,j} W[i,j]·Θ_(ij)[p,q]``

The second O(N²) contraction of the KRK-Picard update (the L₂ half), with
`W = L₁`. The grid walks the (i, j) block index space; the (N₂×N₂) output
accumulator stays VMEM-resident across the whole grid (constant BlockSpec),
is zeroed on the first instance, and each instance adds one scaled Θ tile —
the canonical Pallas reduction-across-grid pattern. Per-instance VMEM:
2·N₂² + 1 elements. interpret=True for CPU-PJRT executability (see
block_trace.py).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _wbs_kernel(theta_ref, w_ref, o_ref):
    i = pl.program_id(0)
    j = pl.program_id(1)

    @pl.when(jnp.logical_and(i == 0, j == 0))
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += w_ref[0, 0] * theta_ref[...]


@functools.partial(jax.jit, static_argnames=("n1", "n2"))
def weighted_block_sum(theta, w, *, n1, n2):
    """A2 = Σ_{ij} W[i,j]·Θ_(ij); returns (n2, n2)."""
    assert theta.shape == (n1 * n2, n1 * n2), theta.shape
    assert w.shape == (n1, n1), w.shape
    return pl.pallas_call(
        _wbs_kernel,
        grid=(n1, n1),
        in_specs=[
            pl.BlockSpec((n2, n2), lambda i, j: (i, j)),
            pl.BlockSpec((1, 1), lambda i, j: (i, j)),
        ],
        out_specs=pl.BlockSpec((n2, n2), lambda i, j: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((n2, n2), theta.dtype),
        interpret=True,
    )(theta, w)
