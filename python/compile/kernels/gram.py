"""Pallas kernel: tiled Gram matrix XᵀX.

Used for kernel construction (`L_i = XᵀX`, §5.1) and the feature-kernel
paths of the data generators. This one IS an MXU-shaped matmul: the grid
tiles the (d, d) output into (bd × bd) blocks and the reduction dimension
n into bn-length panels; each instance performs a (bd×bn)·(bn×bd)
contraction — on TPU that is a systolic-array matmul per instance with a
VMEM accumulator (2·bn·bd + bd² elements resident). Block sizes default to
MXU-aligned 128 where the problem is large enough. interpret=True on this
image (see block_trace.py).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _gram_kernel(x1_ref, x2_ref, o_ref):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    # (bd, bn) @ (bn, bd) panel product accumulated over the k grid axis.
    o_ref[...] += x1_ref[...].T @ x2_ref[...]


def _pick_block(total, preferred):
    """Largest divisor of `total` that is ≤ preferred (≥1)."""
    b = min(preferred, total)
    while total % b != 0:
        b -= 1
    return b


@functools.partial(jax.jit, static_argnames=("bn", "bd"))
def gram(x, *, bn=128, bd=128):
    """XᵀX for X of shape (n, d); returns (d, d)."""
    n, d = x.shape
    bn = _pick_block(n, bn)
    bd = _pick_block(d, bd)
    return pl.pallas_call(
        _gram_kernel,
        grid=(d // bd, d // bd, n // bn),
        in_specs=[
            pl.BlockSpec((bn, bd), lambda i, j, k: (k, i)),
            pl.BlockSpec((bn, bd), lambda i, j, k: (k, j)),
        ],
        out_specs=pl.BlockSpec((bd, bd), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((d, d), x.dtype),
        interpret=True,
    )(x, x)
