"""Pallas kernel: the A1 block-trace contraction (App. B.1).

``A1[k,l] = Tr(Θ_(kl)·L₂) = Σ_{p,q} Θ_(kl)[p,q]·L₂[q,p]``

This is the O(N²) hot spot of the batch KRK-Picard update (Thm. 3.3): Θ is
the only N×N object the algorithm touches, and this kernel reads it exactly
once.

TPU mapping (see DESIGN.md §Hardware-Adaptation): the grid is the (k, l)
block index space; each program instance streams one (N₂×N₂) tile of Θ
HBM→VMEM while L₂ᵀ stays VMEM-resident across the whole grid (its BlockSpec
index map is constant). VMEM footprint per instance = 2·N₂² elements
(≈ 160 KiB at N₂ = 100, f64), comfortably inside a TPU core's ~16 MiB VMEM,
and the multiply-reduce maps onto the VPU (it is a Frobenius inner product,
not an MXU matmul). On this image Pallas must run interpret=True (the CPU
PJRT plugin cannot execute Mosaic custom-calls), so these kernels are
correctness-validated here and their TPU characteristics are estimated
statically (DESIGN.md §7).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _block_trace_kernel(theta_ref, l2t_ref, o_ref):
    # One (k, l) tile: Frobenius inner product <Θ_(kl), L₂ᵀ>.
    o_ref[0, 0] = jnp.sum(theta_ref[...] * l2t_ref[...])


@functools.partial(jax.jit, static_argnames=("n1", "n2"))
def block_trace(theta, l2, *, n1, n2):
    """A1[k,l] = Tr(Θ_(kl)·L₂) for all (k,l); returns (n1, n1)."""
    assert theta.shape == (n1 * n2, n1 * n2), theta.shape
    assert l2.shape == (n2, n2), l2.shape
    l2t = l2.T  # contract Θ_(kl)[p,q]·L2[q,p] as elementwise with L2ᵀ
    return pl.pallas_call(
        _block_trace_kernel,
        grid=(n1, n1),
        in_specs=[
            pl.BlockSpec((n2, n2), lambda k, l: (k, l)),
            pl.BlockSpec((n2, n2), lambda k, l: (0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1), lambda k, l: (k, l)),
        out_shape=jax.ShapeDtypeStruct((n1, n1), theta.dtype),
        interpret=True,
    )(theta, l2t)
