"""Pure-jnp oracles for the Pallas kernels (the CORE correctness signal).

Every Layer-1 kernel in this package has a reference implementation here,
written as a direct einsum/dot transcription of the paper's formulas
(App. B). pytest + hypothesis sweep shapes and dtypes asserting allclose
between kernel and oracle; the Rust runtime parity tests then compare the
AOT-compiled artifacts against the same math re-implemented in Rust.
"""

import jax.numpy as jnp


def block_trace_ref(theta, l2, n1, n2):
    """A1[k,l] = Tr(Θ_(kl) · L2)  (App. B.1).

    Θ is (n1·n2, n1·n2); the (k,l) block is Θ[k·n2:(k+1)·n2, l·n2:(l+1)·n2].
    Tr(Θ_(kl) L2) = Σ_{p,q} Θ_(kl)[p,q] · L2[q,p].
    """
    t = theta.reshape(n1, n2, n1, n2)  # [k, p, l, q]
    return jnp.einsum("kplq,qp->kl", t, l2)


def weighted_block_sum_ref(theta, w, n1, n2):
    """A2 = Σ_{i,j} W[i,j] · Θ_(ij)  (App. B.2), an (n2, n2) matrix."""
    t = theta.reshape(n1, n2, n1, n2)  # [i, p, j, q]
    return jnp.einsum("ipjq,ij->pq", t, w)


def gram_ref(x):
    """Gram matrix XᵀX (kernel construction: L_i = XᵀX, §5.1)."""
    return x.T @ x


def picard_ldl_ref(l, delta):
    """One full Picard step body: L + L·Δ·L (Eq. 5; step size folded
    into Δ by the caller)."""
    return l + l @ delta @ l


def sandwich_ref(outer, inner):
    """outer · inner · outer — the L₁·A₁·L₁ / L₂·A₂·L₂ pattern."""
    return outer @ inner @ outer
