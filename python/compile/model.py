"""Layer-2 JAX compute graphs for KronDPP learning.

These are the dense graphs that `aot.py` lowers to HLO text for the Rust
runtime. Each graph calls the Layer-1 Pallas kernels for its contraction
hot spot, so the kernels lower into the same HLO module and ship inside
the same artifact. Eigendecompositions deliberately stay on the Rust side
(jax's `eigh` lowers to LAPACK custom-calls the pinned xla_extension CPU
runtime cannot execute — DESIGN.md §3); the graphs here are pure
dot/reduce/elementwise and therefore portable.

All functions are shape-polymorphic in Python but lowered per size variant
at AOT time (static shapes are a PJRT requirement).
"""

import jax
import jax.numpy as jnp

jax.config.update("jax_enable_x64", True)

from .kernels.block_trace import block_trace
from .kernels.gram import gram
from .kernels.weighted_block_sum import weighted_block_sum


def krk_l1_term(theta, l1, l2, *, n1, n2):
    """The Θ-half of the L₁ update: `L₁·A₁·L₁` with
    `A₁[k,l] = Tr(Θ_(kl)L₂)` (App. B.1). Returns (n1, n1).

    The Rust coordinator subtracts its eigen-space `L₁BL₁` term and applies
    the step size — see `learn::krk`.
    """
    a1 = block_trace(theta, l2, n1=n1, n2=n2)
    return (l1 @ a1 @ l1,)


def krk_l2_term(theta, l1, l2, *, n1, n2):
    """The Θ-half of the L₂ update: `L₂·A₂·L₂` with
    `A₂ = Σ_{ij} L1_{ij}Θ_(ij)` (App. B.2). Returns (n2, n2)."""
    a2 = weighted_block_sum(theta, l1, n1=n1, n2=n2)
    return (l2 @ a2 @ l2,)


def krk_contractions(theta, l1, l2, *, n1, n2):
    """Both raw contractions `(A₁, A₂)` in one artifact — the exact
    interface of the Rust `Contractions` backend trait."""
    a1 = block_trace(theta, l2, n1=n1, n2=n2)
    a2 = weighted_block_sum(theta, l1, n1=n1, n2=n2)
    return (a1, a2)


def picard_ldl(l, delta):
    """Full-Picard step body `L + L·Δ·L` (Eq. 5) — the N³ hot spot of the
    baseline. Step size is folded into Δ by the caller."""
    return (l + l @ delta @ l,)


def gram_kernel_fn(x):
    """Sub-kernel construction `XᵀX` (§5.1) via the tiled Pallas gram."""
    return (gram(x),)


def l_plus_i_inverse_action(p1, p2, d1, d2, rhs, *, n1, n2):
    """`(I + L₁⊗L₂)⁻¹ · rhs` through the factored eigenbasis (Cor. 2.2):
    reshape rhs to (n1, n2), rotate into the eigenbasis, scale by
    `1/(1+d1ᵢd2ⱼ)`, rotate back. O(N^{3/2}) instead of O(N³).

    Used by the serving coordinator's conditioning paths.
    """
    r = rhs.reshape(n1, n2)
    # into eigenbasis: P₁ᵀ R P₂
    z = p1.T @ r @ p2
    denom = 1.0 + d1[:, None] * d2[None, :]
    z = z / denom
    out = p1 @ z @ p2.T
    return (out.reshape(n1 * n2),)
